//! SIMD gather decode for v2 multi-state streams, behind a cross-ISA
//! backend seam.
//!
//! The const-generic scalar loop in [`super::multistate`] already gives
//! the out-of-order core `N` independent multiply/refill chains; this
//! module takes the remaining step rans_static-style coders take to
//! reach memory-bound throughput (its `rans_word_sse41` shape): retire
//! one whole decode round per *vector* instead of per chain.
//!
//! One vectorized round over `N` states is three stages:
//!
//! 1. **Gather** the `N` fused 8-byte [`DecEntry`] slots addressed by
//!    `state & (SCALE−1)`. On SSE4.1 there is no gather instruction, so
//!    the four slots are emulated with four scalar `u64` loads packed
//!    into vectors (`vpgatherqq`-shaped, materialized as `_mm_set_epi64x`
//!    pairs); on AVX2 two `vpgatherdd`s fetch the per-entry dword halves
//!    of all eight slots directly; NEON ([`super::neon`]) mirrors the
//!    SSE4.1 scalar-load-and-pack shape (AArch64 has no gather either).
//!    Either way one permute per field splits the entries into `freq`,
//!    `bias`, and `sym` vectors — [`DecEntry`]'s explicit zeroed padding
//!    is what makes the raw 8-byte loads defined behavior.
//! 2. **Transition** all states at once with a packed 32-bit multiply:
//!    `state ← freq · (state >> SCALE_BITS) + bias`
//!    (`_mm_mullo_epi32` / `_mm256_mullo_epi32` / `vmlaq_u32`; the
//!    product provably fits 32 bits, see [`super::decode`]).
//! 3. **Refill** the states that dropped below `2^16` from the shared
//!    byte cursor: a movemask turns the per-lane `state < 2^16` compare
//!    into an `N`-bit mask, a 16-entry byte-shuffle control table
//!    ([`REFILL_SHUF`], `pshufb` on x86, `vqtbl1q_u8` on NEON) routes
//!    the next `popcount` 16-bit words to their lanes in state order
//!    (the wire contract: state 0 refills first), and a blend merges
//!    them in. `2·popcount` bytes advance the cursor.
//!
//! The vector loop runs while a full round's worst-case refill
//! (`2·N` bytes) is guaranteed in bounds; the tail of the stream — plus
//! the `count mod N` symbols and all end-of-stream validation — is
//! handed to the *same* scalar helpers the portable decoder uses
//! ([`multistate::scalar_rounds`] / [`multistate::finish`]), so the two
//! paths cannot diverge on validation. Symbol-identity of the vector
//! rounds themselves is pinned by `rust/tests/rans_differential.rs`
//! (differential fuzz vs. the scalar loop) and by decoding the
//! committed golden vectors through every compiled-in backend.
//!
//! # The backend seam
//!
//! Every decode implementation lives behind the object-safe
//! [`DecodeBackend`] trait; the [`Backend`] enum names them and
//! [`Backend::implementation`] resolves to the `'static` trait object.
//! All four impls are compiled on every target — `cfg(target_arch)`
//! lives *only inside* the impl bodies, never at call sites — so
//! dispatch logic, tests, and benches are ISA-independent, and a new
//! backend (AVX-512, a GPU offload stub) is a new impl plus an enum
//! variant, not another `cfg` thicket.
//!
//! Dispatch is at runtime ([`backend_for`]): 4-state streams use SSE4.1
//! (x86_64) or NEON (aarch64), 8-state streams use AVX2 or NEON, and
//! everything falls back to the scalar loop. No wire format change, no
//! build flags. Forcing a specific backend goes through
//! [`decode_multistate_with`] (the seam the differential tests and
//! benchmarks pin the dispatcher through) or the process-wide
//! [`FORCE_BACKEND_ENV`] environment override, which rejects unknown or
//! unavailable backends loudly instead of silently falling back.
//!
//! [`DecEntry`]: super::symbol::DecEntry

use std::sync::OnceLock;

use crate::error::{Error, Result};

use super::freq::{FreqTable, SCALE};
use super::multistate;
use super::neon::NeonBackend;

/// Environment variable force-selecting a decode backend process-wide:
/// `scalar`, `sse4.1`, `avx2`, or `neon` (empty or `auto` keeps runtime
/// dispatch). The CI matrix legs and benches use it to pin which path
/// actually ran. An unknown name, or a backend this host cannot run, is
/// a loud [`Error::Invalid`] from every dispatch — never a silent
/// scalar fallback. Streams whose width the forced backend does not
/// cover (e.g. v1 scalar streams under `neon`) still decode through the
/// scalar loop, so mixed-layout traffic keeps working.
///
/// The variable is read once per process and cached; changing it after
/// the first decode has no effect.
pub const FORCE_BACKEND_ENV: &str = "RANS_SC_FORCE_BACKEND";

/// A decode implementation the dispatcher can select.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The portable const-generic scalar loop (always available).
    Scalar,
    /// SSE4.1 4-state path: emulated 8-byte gathers + `pmulld` +
    /// movemask/`pshufb` refill.
    Sse41,
    /// AVX2 8-state path: `vpgatherdd` slot fetch + `vpmulld` +
    /// split-half movemask/`pshufb` refill.
    Avx2,
    /// NEON 4- and 8-state path (aarch64): scalar-load-and-pack entry
    /// gathers + `vmlaq_u32` + `vqtbl1q_u8` refill routing.
    Neon,
}

/// Every backend compiled into this build, in dispatch-preference order
/// (the auto dispatcher picks the first available entry covering the
/// stream's width; scalar is the universal fallback).
pub const ALL_BACKENDS: [Backend; 4] =
    [Backend::Sse41, Backend::Avx2, Backend::Neon, Backend::Scalar];

impl Backend {
    /// Human-readable name (benchmark reports, CI job summaries).
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Sse41 => "sse4.1",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// Parse a backend name (the [`FORCE_BACKEND_ENV`] value syntax).
    pub fn parse(name: &str) -> Result<Backend> {
        match name {
            "scalar" => Ok(Backend::Scalar),
            "sse4.1" | "sse41" => Ok(Backend::Sse41),
            "avx2" => Ok(Backend::Avx2),
            "neon" => Ok(Backend::Neon),
            other => Err(Error::invalid(format!(
                "unknown decode backend '{other}' (expected scalar, sse4.1, avx2, or neon)"
            ))),
        }
    }

    /// The implementation behind this name. Always resolves — whether
    /// the impl can *run* here is [`DecodeBackend::available`].
    pub fn implementation(&self) -> &'static dyn DecodeBackend {
        match self {
            Backend::Scalar => &ScalarBackend,
            Backend::Sse41 => &Sse41Backend,
            Backend::Avx2 => &Avx2Backend,
            Backend::Neon => &NeonBackend,
        }
    }

    /// True iff this backend decodes `n_states`-state streams. Unlike a
    /// single fixed width, this is a predicate: NEON covers both 4- and
    /// 8-state streams, scalar covers every supported count.
    pub fn supports(&self, n_states: usize) -> bool {
        self.implementation().supports_states(n_states)
    }
}

/// The object-safe surface every decode backend implements — the seam
/// that keeps `cfg(target_arch)` out of dispatch logic, tests, and
/// benches. All impls are compiled on every target; target-gated code
/// lives only inside method bodies.
pub trait DecodeBackend: Send + Sync {
    /// The [`Backend`] name this implementation answers to.
    fn id(&self) -> Backend;

    /// True iff this implementation can run on this host (compile
    /// target + runtime feature detection).
    fn available(&self) -> bool;

    /// True iff this implementation decodes `n_states`-state streams.
    fn supports_states(&self, n_states: usize) -> bool;

    /// Decode exactly `count` symbols from an `n_states`-state stream.
    ///
    /// Self-validating: errors with [`Error::Invalid`] when the backend
    /// is unavailable on this host or does not cover `n_states`, so a
    /// direct call can never execute an ISA the CPU lacks. (The
    /// dispatch wrappers check the same preconditions first for
    /// friendlier errors.)
    fn decode(
        &self,
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
        n_states: usize,
    ) -> Result<Vec<u32>>;
}

/// [`Error::Invalid`] for a backend asked to decode a width it does not
/// cover.
pub(crate) fn width_error(backend: Backend, n_states: usize) -> Error {
    Error::invalid(format!(
        "backend {} does not decode {n_states}-state streams",
        backend.name()
    ))
}

/// [`Error::Invalid`] for a backend this host cannot run.
pub(crate) fn unavailable_error(backend: Backend) -> Error {
    Error::invalid(format!("backend {} is not available on this host", backend.name()))
}

/// The portable const-generic scalar loop as a [`DecodeBackend`].
struct ScalarBackend;

impl DecodeBackend for ScalarBackend {
    fn id(&self) -> Backend {
        Backend::Scalar
    }

    fn available(&self) -> bool {
        true
    }

    fn supports_states(&self, n_states: usize) -> bool {
        multistate::supported_states(n_states)
    }

    fn decode(
        &self,
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
        n_states: usize,
    ) -> Result<Vec<u32>> {
        multistate::decode_multistate_scalar(bytes, count, table, n_states)
    }
}

/// The SSE4.1 4-state gather decoder as a [`DecodeBackend`].
struct Sse41Backend;

impl DecodeBackend for Sse41Backend {
    fn id(&self) -> Backend {
        Backend::Sse41
    }

    fn available(&self) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("sse4.1")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    }

    fn supports_states(&self, n_states: usize) -> bool {
        n_states == 4
    }

    fn decode(
        &self,
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
        n_states: usize,
    ) -> Result<Vec<u32>> {
        if n_states != 4 {
            return Err(width_error(self.id(), n_states));
        }
        if !self.available() {
            return Err(unavailable_error(self.id()));
        }
        #[cfg(target_arch = "x86_64")]
        {
            // SAFETY: the sse4.1 target feature was verified present at
            // runtime by `available()` above — `x86::decode4`'s only
            // precondition.
            unsafe { x86::decode4(bytes, count, table) }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (bytes, count, table);
            unreachable!("sse4.1 reported available on a non-x86_64 build")
        }
    }
}

/// The AVX2 8-state gather decoder as a [`DecodeBackend`].
struct Avx2Backend;

impl DecodeBackend for Avx2Backend {
    fn id(&self) -> Backend {
        Backend::Avx2
    }

    fn available(&self) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx2")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    }

    fn supports_states(&self, n_states: usize) -> bool {
        n_states == 8
    }

    fn decode(
        &self,
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
        n_states: usize,
    ) -> Result<Vec<u32>> {
        if n_states != 8 {
            return Err(width_error(self.id(), n_states));
        }
        if !self.available() {
            return Err(unavailable_error(self.id()));
        }
        #[cfg(target_arch = "x86_64")]
        {
            // SAFETY: avx2 verified present at runtime by `available()`
            // above — `x86::decode8`'s only precondition.
            unsafe { x86::decode8(bytes, count, table) }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = (bytes, count, table);
            unreachable!("avx2 reported available on a non-x86_64 build")
        }
    }
}

/// True iff `backend` can run on this host (compile target + runtime
/// feature detection).
pub fn backend_available(backend: Backend) -> bool {
    backend.implementation().available()
}

/// Resolve a [`FORCE_BACKEND_ENV`] value: empty / `auto` means no
/// forcing; anything else must name a backend this host can run.
fn resolve_forced(spec: &str) -> Result<Option<Backend>> {
    if spec.is_empty() || spec == "auto" {
        return Ok(None);
    }
    let backend =
        Backend::parse(spec).map_err(|e| Error::invalid(format!("{FORCE_BACKEND_ENV}: {e}")))?;
    if !backend_available(backend) {
        return Err(Error::invalid(format!(
            "{FORCE_BACKEND_ENV}={spec}: backend is not available on this host"
        )));
    }
    Ok(Some(backend))
}

/// The process-wide forced backend from [`FORCE_BACKEND_ENV`], if any.
/// Read once and cached (the override is process configuration, not
/// per-call state); an invalid value errors on *every* dispatch so a
/// misspelled CI matrix leg cannot silently measure the wrong path.
pub fn forced_backend() -> Result<Option<Backend>> {
    static FORCED: OnceLock<std::result::Result<Option<Backend>, String>> = OnceLock::new();
    FORCED
        .get_or_init(|| match std::env::var(FORCE_BACKEND_ENV) {
            Ok(spec) => resolve_forced(&spec).map_err(|e| e.to_string()),
            Err(_) => Ok(None),
        })
        .clone()
        .map_err(Error::invalid)
}

/// The backend [`super::multistate::decode_multistate`] dispatches to
/// for `n_states`-state streams on this host: the [`FORCE_BACKEND_ENV`]
/// override when set (scalar for widths it does not cover), otherwise
/// the first available entry of [`ALL_BACKENDS`] covering the width.
///
/// Errors only when the override names an unknown or unavailable
/// backend.
pub fn backend_for(n_states: usize) -> Result<Backend> {
    if let Some(forced) = forced_backend()? {
        // A forced backend applies wherever it covers the stream's
        // width; other widths still run scalar (a CI leg forcing neon
        // must not reject the v1 scalar streams in the same container).
        return Ok(if forced.supports(n_states) { forced } else { Backend::Scalar });
    }
    for backend in ALL_BACKENDS {
        if backend.supports(n_states) && backend_available(backend) {
            return Ok(backend);
        }
    }
    Ok(Backend::Scalar)
}

/// Decode through the backend [`backend_for`] picks — the
/// implementation behind [`super::multistate::decode_multistate`].
pub(crate) fn dispatch_decode(
    bytes: &[u8],
    count: usize,
    table: &FreqTable,
    n_states: usize,
) -> Result<Vec<u32>> {
    let backend = backend_for(n_states)?;
    if backend == Backend::Scalar {
        return multistate::decode_multistate_scalar(bytes, count, table, n_states);
    }
    // Auto dispatch (unlike forcing) tolerates a fused table that does
    // not span the slot space: the SIMD impls take their internal
    // bounds-checked scalar fallback in that case.
    backend.implementation().decode(bytes, count, table, n_states)
}

/// Decode forcing a specific `backend` — the seam the differential
/// tests and benchmarks pin the dispatcher through, so a builder
/// without SSE can never silently compare scalar against scalar.
///
/// Errors with `Error::Invalid` when the backend is unavailable on this
/// host or does not cover `n_states` (SSE4.1 ⇒ 4 states, AVX2 ⇒ 8,
/// NEON ⇒ 4 or 8, scalar ⇒ any supported count).
pub fn decode_multistate_with(
    bytes: &[u8],
    count: usize,
    table: &FreqTable,
    n_states: usize,
    backend: Backend,
) -> Result<Vec<u32>> {
    let imp = backend.implementation();
    if !imp.supports_states(n_states) {
        return Err(width_error(backend, n_states));
    }
    if backend != Backend::Scalar {
        if !imp.available() {
            return Err(unavailable_error(backend));
        }
        // The SIMD paths guard their unsafe gathers by falling back to
        // the scalar loop if the fused table ever failed to span the
        // slot space; when a backend was *forced*, that silent fallback
        // would defeat the differential seam — error loudly instead.
        if table.dec_table().len() != SCALE as usize {
            return Err(Error::invalid("fused decode table does not span the slot space"));
        }
    }
    imp.decode(bytes, count, table, n_states)
}

/// Byte-shuffle control table for the movemask-driven refill, indexed
/// by the `need-refill` lane mask `m` (4 bits, so 16 entries — the AVX2
/// path indexes it twice, once per 128-bit half, and the NEON 8-state
/// path does the same per `uint32x4_t` half). Drives `pshufb` on x86
/// and `vqtbl1q_u8` on NEON: both zero any destination byte whose
/// control byte is out of range (`0x80`), so one table serves both
/// ISAs.
///
/// For each 32-bit lane `j` with bit `j` set in `m`, the control routes
/// source bytes `2k` and `2k+1` (the `k`-th 16-bit stream word, where
/// `k` is the number of refilling lanes below `j`) into the lane's low
/// half and zeroes its high half; lanes not refilling are fully zeroed
/// (`0x80` control bytes) and the subsequent blend keeps their state.
/// This reproduces the wire contract that refills consume the shared
/// cursor in state order, `2·popcount(m)` bytes per round.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64", test))]
const fn refill_shuffles() -> [[u8; 16]; 16] {
    let mut table = [[0x80u8; 16]; 16];
    let mut m = 0usize;
    while m < 16 {
        let mut next_word = 0u8;
        let mut lane = 0usize;
        while lane < 4 {
            if m & (1 << lane) != 0 {
                table[m][4 * lane] = 2 * next_word;
                table[m][4 * lane + 1] = 2 * next_word + 1;
                next_word += 1;
            }
            lane += 1;
        }
        m += 1;
    }
    table
}

/// See [`refill_shuffles`].
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64", test))]
pub(crate) static REFILL_SHUF: [[u8; 16]; 16] = refill_shuffles();

#[cfg(target_arch = "x86_64")]
mod x86 {
    #![deny(unsafe_op_in_unsafe_fn)]

    use core::arch::x86_64::*;

    use crate::error::Result;
    use crate::rans::freq::{FreqTable, SCALE, SCALE_BITS};
    use crate::rans::multistate::{decode_n, finish, read_states, scalar_rounds};

    use super::REFILL_SHUF;

    /// Decode a 4-state stream, vectorizing one round (4 symbols) per
    /// iteration with SSE4.1.
    ///
    /// # Safety
    ///
    /// The caller must have verified at runtime that this CPU supports
    /// `sse4.1` (e.g. via `is_x86_feature_detected!`).
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn decode4(
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
    ) -> Result<Vec<u32>> {
        let dec = table.dec_table();
        // Gather-index invariant: the unsafe loads below index the table
        // with `state & (SCALE−1)`, so it must span the full slot space.
        // Every FreqTable constructor upholds this, but the SIMD path
        // must not lean on a debug-only assert — if a future constructor
        // ever breaks the invariant, take the bounds-checked scalar loop
        // instead of reading out of bounds in release builds.
        if dec.len() != SCALE as usize {
            return decode_n::<4>(bytes, count, table);
        }
        let mut states = read_states::<4>(bytes)?;
        let mut pos = 16usize;
        // Same untrusted-header reservation cap as the scalar decoder.
        let mut out: Vec<u32> = Vec::with_capacity(count.min(1 << 20));
        let entries = dec.as_ptr().cast::<u64>();

        let full_rounds = count / 4;
        let mut rounds_done = 0usize;

        // SAFETY: `states` is a `[u32; 4]` — exactly the 16 bytes an
        // unaligned vector load reads.
        let mut sv = unsafe { _mm_loadu_si128(states.as_ptr().cast()) };
        let slot_mask = _mm_set1_epi32((SCALE - 1) as i32);
        let low16 = _mm_set1_epi32(0xFFFF);
        let zero = _mm_setzero_si128();

        // One round's refill consumes at most 2 bytes per state; run the
        // vector loop only while that worst case (8 bytes) is in bounds
        // and let the scalar finisher handle the stream tail.
        while rounds_done < full_rounds && pos + 8 <= bytes.len() {
            // Stage 1: gather the four fused 8-byte DecEntry slots.
            let slots = _mm_and_si128(sv, slot_mask);
            let mut idx = [0u32; 4];
            // SAFETY: `idx` is a `[u32; 4]` — exactly 16 writable bytes.
            unsafe { _mm_storeu_si128(idx.as_mut_ptr().cast(), slots) };
            // SAFETY: every index is `state & (SCALE−1) < SCALE` and the
            // fused table holds exactly SCALE 8-byte entries (checked on
            // entry), all bytes initialized (DecEntry's explicit zero
            // padding) — so the four u64 loads are in bounds and read
            // only initialized memory.
            let (e0, e1, e2, e3) = unsafe {
                (
                    *entries.add(idx[0] as usize),
                    *entries.add(idx[1] as usize),
                    *entries.add(idx[2] as usize),
                    *entries.add(idx[3] as usize),
                )
            };
            // Pack into vectors: lane order [e0, e1] / [e2, e3].
            let lo = _mm_set_epi64x(e1 as i64, e0 as i64);
            let hi = _mm_set_epi64x(e3 as i64, e2 as i64);
            // Split each entry into its dword halves (little-endian
            // DecEntry layout): sf = sym | freq << 16, bp = bias | 0.
            let sf = _mm_castps_si128(_mm_shuffle_ps::<0b10_00_10_00>(
                _mm_castsi128_ps(lo),
                _mm_castsi128_ps(hi),
            ));
            let bp = _mm_castps_si128(_mm_shuffle_ps::<0b11_01_11_01>(
                _mm_castsi128_ps(lo),
                _mm_castsi128_ps(hi),
            ));
            let freq = _mm_srli_epi32::<16>(sf);
            let sym = _mm_and_si128(sf, low16);
            let bias = _mm_and_si128(bp, low16);

            // Stage 2: four independent transitions in one packed
            // multiply — state ← freq · (state >> SCALE_BITS) + bias.
            let shifted = _mm_srli_epi32::<{ SCALE_BITS as i32 }>(sv);
            sv = _mm_add_epi32(_mm_mullo_epi32(freq, shifted), bias);

            // Stage 3: movemask-driven refill of states below 2^16.
            let need = _mm_cmpeq_epi32(_mm_srli_epi32::<16>(sv), zero);
            let m = _mm_movemask_ps(_mm_castsi128_ps(need)) as usize;
            // SAFETY: the loop guard holds pos + 8 <= bytes.len(), so
            // the 8-byte word load is in bounds.
            let words_raw = unsafe { _mm_loadl_epi64(bytes.as_ptr().add(pos).cast()) };
            // SAFETY: `m` is a 4-bit movemask (< 16) indexing the
            // 16-entry control table; each entry is 16 bytes.
            let ctrl = unsafe { _mm_loadu_si128(REFILL_SHUF[m].as_ptr().cast()) };
            let words = _mm_shuffle_epi8(words_raw, ctrl);
            let refilled = _mm_or_si128(_mm_slli_epi32::<16>(sv), words);
            sv = _mm_blendv_epi8(sv, refilled, need);
            pos += 2 * m.count_ones() as usize;

            // Emit the round's symbols in state order (the schedule).
            let mut sy = [0u32; 4];
            // SAFETY: `sy` is a `[u32; 4]` — exactly 16 writable bytes.
            unsafe { _mm_storeu_si128(sy.as_mut_ptr().cast(), sym) };
            out.extend_from_slice(&sy);
            rounds_done += 1;
        }

        // SAFETY: `states` is a `[u32; 4]` — exactly 16 writable bytes.
        unsafe { _mm_storeu_si128(states.as_mut_ptr().cast(), sv) };
        // Remaining rounds, tail symbols, and all validation run through
        // the scalar helpers — shared code, shared failure behavior.
        let remaining = full_rounds - rounds_done;
        scalar_rounds::<4>(bytes, &mut pos, &mut states, &mut out, remaining, dec)?;
        finish::<4>(bytes, &mut pos, &mut states, &mut out, count % 4, dec)?;
        Ok(out)
    }

    /// Decode an 8-state stream, vectorizing one round (8 symbols) per
    /// iteration with AVX2.
    ///
    /// # Safety
    ///
    /// The caller must have verified at runtime that this CPU supports
    /// `avx2` (e.g. via `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn decode8(
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
    ) -> Result<Vec<u32>> {
        let dec = table.dec_table();
        // Same release-mode gather-index guard as `decode4` above.
        if dec.len() != SCALE as usize {
            return decode_n::<8>(bytes, count, table);
        }
        let mut states = read_states::<8>(bytes)?;
        let mut pos = 32usize;
        let mut out: Vec<u32> = Vec::with_capacity(count.min(1 << 20));
        let base = dec.as_ptr().cast::<i32>();

        let full_rounds = count / 8;
        let mut rounds_done = 0usize;

        // SAFETY: `states` is a `[u32; 8]` — exactly the 32 bytes an
        // unaligned vector load reads.
        let mut sv = unsafe { _mm256_loadu_si256(states.as_ptr().cast()) };
        let slot_mask = _mm256_set1_epi32((SCALE - 1) as i32);
        let low16 = _mm256_set1_epi32(0xFFFF);
        let zero = _mm256_setzero_si256();

        // Worst-case refill per round is 2 bytes × 8 states = 16 bytes.
        while rounds_done < full_rounds && pos + 16 <= bytes.len() {
            // Stage 1: two dword gathers fetch both halves of all eight
            // fused entries (base + slot·8 → sym | freq << 16, and
            // base + slot·8 + 4 → bias; padding is zero).
            let slots = _mm256_and_si256(sv, slot_mask);
            // SAFETY: every gathered dword lies inside entry
            // `slot < SCALE` of the fused table (length checked on
            // entry, 8 bytes per entry, all bytes initialized), so the
            // gather at byte offset slot·8 is in bounds.
            let sf = unsafe { _mm256_i32gather_epi32::<8>(base, slots) };
            // SAFETY: as above for the entry's second dword at byte
            // offset slot·8 + 4.
            let bp = unsafe { _mm256_i32gather_epi32::<8>(base.add(1), slots) };
            let freq = _mm256_srli_epi32::<16>(sf);
            let sym = _mm256_and_si256(sf, low16);
            let bias = _mm256_and_si256(bp, low16);

            // Stage 2: eight transitions in one packed multiply.
            let shifted = _mm256_srli_epi32::<{ SCALE_BITS as i32 }>(sv);
            sv = _mm256_add_epi32(_mm256_mullo_epi32(freq, shifted), bias);

            // Stage 3: refill, split into the two 128-bit halves so the
            // 16-entry shuffle table serves both; the upper half's word
            // load starts after the bytes the lower half consumes,
            // preserving the state-order wire contract.
            let need = _mm256_cmpeq_epi32(_mm256_srli_epi32::<16>(sv), zero);
            let m = _mm256_movemask_ps(_mm256_castsi256_ps(need)) as usize;
            let m_lo = m & 0xF;
            let m_hi = m >> 4;
            let lo_bytes = 2 * m_lo.count_ones() as usize;
            // SAFETY: the loop guard holds pos + 16 <= bytes.len(), so
            // the lower half's 8-byte word load is in bounds.
            let w_lo = unsafe { _mm_loadl_epi64(bytes.as_ptr().add(pos).cast()) };
            // SAFETY: lo_bytes ≤ 8 and pos + 16 <= bytes.len(), so the
            // upper half's 8-byte load at pos + lo_bytes is in bounds.
            let w_hi = unsafe { _mm_loadl_epi64(bytes.as_ptr().add(pos + lo_bytes).cast()) };
            // SAFETY: `m_lo` is a 4-bit mask (< 16) indexing the
            // 16-entry control table; each entry is 16 bytes.
            let ctrl_lo = unsafe { _mm_loadu_si128(REFILL_SHUF[m_lo].as_ptr().cast()) };
            // SAFETY: as above for `m_hi` (< 16).
            let ctrl_hi = unsafe { _mm_loadu_si128(REFILL_SHUF[m_hi].as_ptr().cast()) };
            let words =
                _mm256_set_m128i(_mm_shuffle_epi8(w_hi, ctrl_hi), _mm_shuffle_epi8(w_lo, ctrl_lo));
            let refilled = _mm256_or_si256(_mm256_slli_epi32::<16>(sv), words);
            sv = _mm256_blendv_epi8(sv, refilled, need);
            pos += 2 * m.count_ones() as usize;

            let mut sy = [0u32; 8];
            // SAFETY: `sy` is a `[u32; 8]` — exactly 32 writable bytes.
            unsafe { _mm256_storeu_si256(sy.as_mut_ptr().cast(), sym) };
            out.extend_from_slice(&sy);
            rounds_done += 1;
        }

        // SAFETY: `states` is a `[u32; 8]` — exactly 32 writable bytes.
        unsafe { _mm256_storeu_si256(states.as_mut_ptr().cast(), sv) };
        let remaining = full_rounds - rounds_done;
        scalar_rounds::<8>(bytes, &mut pos, &mut states, &mut out, remaining, dec)?;
        finish::<8>(bytes, &mut pos, &mut states, &mut out, count % 8, dec)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rans::multistate::{decode_multistate, encode_multistate};
    use crate::util::prng::Rng;

    fn sample(seed: u64, len: usize, alphabet: usize) -> (Vec<u32>, FreqTable) {
        let mut rng = Rng::new(seed);
        let symbols: Vec<u32> = (0..len).map(|_| rng.zipf(alphabet, 1.2) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, alphabet);
        (symbols, table)
    }

    /// The invariant behind every `REFILL_SHUF[m]` unsafe index and the
    /// movemask-driven byte routing: lane `j` refilling receives the
    /// `k`-th stream word (k = refilling lanes below j), everything
    /// else is zeroed, and exactly `2·popcount(m)` source bytes are
    /// referenced.
    #[test]
    fn refill_shuffle_table_routes_words_in_state_order() {
        assert_eq!(REFILL_SHUF.len(), 16);
        for (m, ctrl) in REFILL_SHUF.iter().enumerate() {
            let mut next_word = 0u8;
            for lane in 0..4 {
                let b = &ctrl[4 * lane..4 * lane + 4];
                if m & (1 << lane) != 0 {
                    assert_eq!(b[0], 2 * next_word, "m={m} lane={lane}");
                    assert_eq!(b[1], 2 * next_word + 1, "m={m} lane={lane}");
                    assert_eq!(&b[2..], &[0x80, 0x80], "m={m} lane={lane}");
                    next_word += 1;
                } else {
                    assert_eq!(b, &[0x80; 4], "m={m} lane={lane}");
                }
            }
            assert_eq!(next_word as u32, (m as u32).count_ones(), "m={m}");
            // Every referenced source byte is within the words actually
            // consumed this round.
            for &c in ctrl.iter().filter(|&&c| c & 0x80 == 0) {
                assert!(c < 2 * next_word, "m={m} control byte {c}");
            }
        }
    }

    /// The gather-index invariant the SIMD loads rely on: the fused
    /// table spans the full masked slot space for any valid table.
    #[test]
    fn dec_table_spans_full_slot_space() {
        for alphabet in [1usize, 2, 100, 4096] {
            let symbols: Vec<u32> = (0..alphabet as u32).collect();
            let table = FreqTable::from_symbols(&symbols, alphabet);
            assert_eq!(table.dec_table().len(), crate::rans::freq::SCALE as usize);
        }
    }

    #[test]
    fn backend_metadata_is_consistent() {
        assert!(backend_available(Backend::Scalar));
        // Width coverage: scalar takes every supported count, the x86
        // backends one width each, NEON both SIMD widths.
        for n in [1usize, 2, 4, 8] {
            assert!(Backend::Scalar.supports(n), "scalar n={n}");
        }
        assert!(!Backend::Scalar.supports(3));
        assert!(Backend::Sse41.supports(4) && !Backend::Sse41.supports(8));
        assert!(Backend::Avx2.supports(8) && !Backend::Avx2.supports(4));
        assert!(Backend::Neon.supports(4) && Backend::Neon.supports(8));
        assert!(!Backend::Neon.supports(1) && !Backend::Neon.supports(2));
        // Names and the id() round trip through the trait objects.
        for backend in ALL_BACKENDS {
            assert_eq!(backend.implementation().id(), backend);
        }
        assert_eq!(Backend::Sse41.name(), "sse4.1");
        assert_eq!(Backend::Neon.name(), "neon");
        // The auto dispatcher only ever picks available backends that
        // cover the stream's width.
        for n in [1usize, 2, 4, 8] {
            let b = backend_for(n).unwrap();
            assert!(backend_available(b), "n={n}");
            assert!(b.supports(n), "n={n} picked {}", b.name());
        }
        // Exactly one of the SIMD families can exist on one target.
        assert!(!(backend_available(Backend::Sse41) && backend_available(Backend::Neon)));
    }

    #[test]
    fn backend_names_parse_and_reject() {
        assert_eq!(Backend::parse("scalar").unwrap(), Backend::Scalar);
        assert_eq!(Backend::parse("sse4.1").unwrap(), Backend::Sse41);
        assert_eq!(Backend::parse("sse41").unwrap(), Backend::Sse41);
        assert_eq!(Backend::parse("avx2").unwrap(), Backend::Avx2);
        assert_eq!(Backend::parse("neon").unwrap(), Backend::Neon);
        assert!(Backend::parse("AVX2").is_err());
        assert!(Backend::parse("sse").is_err());
        assert!(Backend::parse("").is_err());
    }

    /// The env-override resolver: empty/auto disable forcing, valid
    /// available names resolve, unknown or unavailable names are loud
    /// errors (never a silent fallback).
    #[test]
    fn force_spec_resolution() {
        assert_eq!(resolve_forced("").unwrap(), None);
        assert_eq!(resolve_forced("auto").unwrap(), None);
        assert_eq!(resolve_forced("scalar").unwrap(), Some(Backend::Scalar));
        assert!(resolve_forced("bogus").is_err());
        for backend in ALL_BACKENDS {
            let resolved = resolve_forced(backend.name());
            if backend_available(backend) {
                assert_eq!(resolved.unwrap(), Some(backend), "{}", backend.name());
            } else {
                assert!(resolved.is_err(), "{}", backend.name());
            }
        }
        // Whatever the suite's environment forces must itself be valid —
        // otherwise every dispatch in this test process errors.
        assert!(forced_backend().is_ok(), "{FORCE_BACKEND_ENV} names an unusable backend");
    }

    #[test]
    fn forcing_mismatched_or_missing_backends_errors() {
        let (symbols, table) = sample(1, 64, 16);
        let bytes = encode_multistate(&symbols, &table, 4).unwrap();
        // Width mismatch is always an error, available or not.
        assert!(decode_multistate_with(&bytes, 64, &table, 8, Backend::Sse41).is_err());
        assert!(decode_multistate_with(&bytes, 64, &table, 4, Backend::Avx2).is_err());
        assert!(decode_multistate_with(&bytes, 64, &table, 2, Backend::Neon).is_err());
        // Scalar backend accepts every supported count.
        assert_eq!(
            decode_multistate_with(&bytes, 64, &table, 4, Backend::Scalar).unwrap(),
            symbols
        );
        // An unavailable SIMD backend is a loud error, not a silent
        // scalar fallback — both through the wrapper and through a
        // direct trait-object call.
        let b8 = encode_multistate(&symbols, &table, 8).unwrap();
        for (backend, stream, n) in [
            (Backend::Sse41, &bytes, 4usize),
            (Backend::Avx2, &b8, 8),
            (Backend::Neon, &bytes, 4),
            (Backend::Neon, &b8, 8),
        ] {
            if !backend_available(backend) {
                assert!(
                    decode_multistate_with(stream, 64, &table, n, backend).is_err(),
                    "{} n={n}",
                    backend.name()
                );
                assert!(
                    backend.implementation().decode(stream, 64, &table, n).is_err(),
                    "direct {} n={n}",
                    backend.name()
                );
            }
        }
    }

    /// Every available backend must agree with the scalar loop across
    /// lengths straddling the round-robin and refill-guard edges.
    #[test]
    fn simd_matches_scalar_on_valid_streams() {
        for (states, backend) in
            [(4usize, Backend::Sse41), (8, Backend::Avx2), (4, Backend::Neon), (8, Backend::Neon)]
        {
            for len in [0usize, 1, 3, 7, 8, 9, 31, 1000, 20_011] {
                for alphabet in [2usize, 64, 300] {
                    let seed = 41 ^ ((len as u64) << 4) ^ states as u64;
                    let (symbols, table) = sample(seed, len, alphabet);
                    let bytes = encode_multistate(&symbols, &table, states).unwrap();
                    let scalar =
                        decode_multistate_with(&bytes, len, &table, states, Backend::Scalar)
                            .unwrap();
                    assert_eq!(scalar, symbols);
                    // The auto path must agree whatever it dispatched to.
                    let auto = decode_multistate(&bytes, len, &table, states).unwrap();
                    assert_eq!(auto, scalar, "auto states={states} len={len}");
                    if backend_available(backend) {
                        let forced =
                            decode_multistate_with(&bytes, len, &table, states, backend).unwrap();
                        assert_eq!(forced, scalar, "forced states={states} len={len}");
                    }
                }
            }
        }
    }

    /// Corrupt streams: SIMD and scalar must agree on acceptance, and
    /// on the decoded symbols whenever both accept.
    #[test]
    fn simd_matches_scalar_on_corrupt_streams() {
        let mut rng = Rng::new(0x51D);
        for (states, backend) in
            [(4usize, Backend::Sse41), (8, Backend::Avx2), (4, Backend::Neon), (8, Backend::Neon)]
        {
            if !backend_available(backend) {
                continue;
            }
            let (symbols, table) = sample(7 + states as u64, 5000, 40);
            let bytes = encode_multistate(&symbols, &table, states).unwrap();
            for _ in 0..200 {
                let mut bad = bytes.clone();
                match rng.below(3) {
                    0 => {
                        let i = rng.below_usize(bad.len());
                        bad[i] ^= 1 << rng.below(8);
                    }
                    1 => {
                        let cut = rng.below_usize(bad.len());
                        bad.truncate(cut);
                    }
                    _ => {
                        bad.push(rng.next_u64() as u8);
                    }
                }
                let scalar =
                    decode_multistate_with(&bad, symbols.len(), &table, states, Backend::Scalar);
                let simd = decode_multistate_with(&bad, symbols.len(), &table, states, backend);
                match (scalar, simd) {
                    (Ok(a), Ok(b)) => assert_eq!(a, b, "states={states}"),
                    (Err(_), Err(_)) => {}
                    (a, b) => panic!(
                        "backends disagree on acceptance (states={states}): \
                         scalar ok={} simd ok={}",
                        a.is_ok(),
                        b.is_ok()
                    ),
                }
            }
        }
    }
}
