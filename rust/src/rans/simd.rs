//! SIMD gather decode for v2 multi-state streams.
//!
//! The const-generic scalar loop in [`super::multistate`] already gives
//! the out-of-order core `N` independent multiply/refill chains; this
//! module takes the remaining step rans_static-style coders take to
//! reach memory-bound throughput (its `rans_word_sse41` shape): retire
//! one whole decode round per *vector* instead of per chain.
//!
//! One vectorized round over `N` states is three stages:
//!
//! 1. **Gather** the `N` fused 8-byte [`DecEntry`] slots addressed by
//!    `state & (SCALE−1)`. On SSE4.1 there is no gather instruction, so
//!    the four slots are emulated with four scalar `u64` loads packed
//!    into vectors (`vpgatherqq`-shaped, materialized as `_mm_set_epi64x`
//!    pairs); on AVX2 two `vpgatherdd`s fetch the per-entry dword halves
//!    of all eight slots directly. Either way one `_mm_shuffle_ps`-class
//!    permute per field splits the entries into `freq`, `bias`, and
//!    `sym` vectors — [`DecEntry`]'s explicit zeroed padding is what
//!    makes the raw 8-byte loads defined behavior.
//! 2. **Transition** all states at once with a packed 32-bit multiply:
//!    `state ← freq · (state >> SCALE_BITS) + bias`
//!    (`_mm_mullo_epi32` / `_mm256_mullo_epi32`; the product provably
//!    fits 32 bits, see [`super::decode`]).
//! 3. **Refill** the states that dropped below `2^16` from the shared
//!    byte cursor: a movemask turns the per-lane `state < 2^16` compare
//!    into an `N`-bit mask, a 16-entry `pshufb` control table
//!    ([`REFILL_SHUF`]) routes the next `popcount` 16-bit words to their
//!    lanes in state order (the wire contract: state 0 refills first),
//!    and a blend merges them in. `2·popcount` bytes advance the cursor.
//!
//! The vector loop runs while a full round's worst-case refill
//! (`2·N` bytes) is guaranteed in bounds; the tail of the stream — plus
//! the `count mod N` symbols and all end-of-stream validation — is
//! handed to the *same* scalar helpers the portable decoder uses
//! ([`multistate::scalar_rounds`] / [`multistate::finish`]), so the two
//! paths cannot diverge on validation. Symbol-identity of the vector
//! rounds themselves is pinned by `rust/tests/rans_differential.rs`
//! (differential fuzz vs. the scalar loop) and by decoding the
//! committed golden vectors through every available backend.
//!
//! Dispatch is at runtime via `is_x86_feature_detected!` — no wire
//! format change, no build flags required: 4-state streams use SSE4.1,
//! 8-state streams use AVX2, and everything falls back to the scalar
//! loop (non-x86_64 builds compile only the fallback). Forcing a
//! specific backend (for the differential tests and benchmarks) goes
//! through [`decode_multistate_with`].

use crate::error::{Error, Result};

use super::freq::{FreqTable, SCALE};
use super::multistate;

/// A decode implementation the dispatcher can select.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The portable const-generic scalar loop (always available).
    Scalar,
    /// SSE4.1 4-state path: emulated 8-byte gathers + `pmulld` +
    /// movemask/`pshufb` refill.
    Sse41,
    /// AVX2 8-state path: `vpgatherdd` slot fetch + `vpmulld` +
    /// split-half movemask/`pshufb` refill.
    Avx2,
}

impl Backend {
    /// Human-readable name (benchmark reports, CI job summaries).
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Sse41 => "sse4.1",
            Backend::Avx2 => "avx2",
        }
    }

    /// The state count this backend's vector width covers (`None` for
    /// the scalar loop, which handles every supported count).
    pub fn states(&self) -> Option<usize> {
        match self {
            Backend::Scalar => None,
            Backend::Sse41 => Some(4),
            Backend::Avx2 => Some(8),
        }
    }
}

/// True iff `backend` can run on this host (compile target + runtime
/// CPUID detection).
pub fn backend_available(backend: Backend) -> bool {
    match backend {
        Backend::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Backend::Sse41 => is_x86_feature_detected!("sse4.1"),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => is_x86_feature_detected!("avx2"),
        #[cfg(not(target_arch = "x86_64"))]
        _ => false,
    }
}

/// The backend [`super::multistate::decode_multistate`] dispatches to
/// for `n_states`-state streams on this host.
pub fn backend_for(n_states: usize) -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if n_states == 4 && is_x86_feature_detected!("sse4.1") {
            return Backend::Sse41;
        }
        if n_states == 8 && is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
    }
    let _ = n_states;
    Backend::Scalar
}

/// Decode a 4-state stream with the best available path (SSE4.1 when
/// the host has it, the scalar loop otherwise).
pub fn decode4(bytes: &[u8], count: usize, table: &FreqTable) -> Result<Vec<u32>> {
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("sse4.1") {
        // SAFETY: the sse4.1 target feature was just verified present
        // at runtime, which is the only precondition of `x86::decode4`.
        return unsafe { x86::decode4(bytes, count, table) };
    }
    multistate::decode_n::<4>(bytes, count, table)
}

/// Decode an 8-state stream with the best available path (AVX2 when the
/// host has it, the scalar loop otherwise).
pub fn decode8(bytes: &[u8], count: usize, table: &FreqTable) -> Result<Vec<u32>> {
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") {
        // SAFETY: the avx2 target feature was just verified present at
        // runtime, which is the only precondition of `x86::decode8`.
        return unsafe { x86::decode8(bytes, count, table) };
    }
    multistate::decode_n::<8>(bytes, count, table)
}

/// Decode forcing a specific `backend` — the seam the differential
/// tests and benchmarks pin the dispatcher through, so a builder
/// without SSE can never silently compare scalar against scalar.
///
/// Errors with `Error::Invalid` when the backend is unavailable on this
/// host or does not cover `n_states` (the SIMD widths are fixed:
/// SSE4.1 ⇒ 4 states, AVX2 ⇒ 8 states).
pub fn decode_multistate_with(
    bytes: &[u8],
    count: usize,
    table: &FreqTable,
    n_states: usize,
    backend: Backend,
) -> Result<Vec<u32>> {
    if let Some(required) = backend.states() {
        if required != n_states {
            return Err(Error::invalid(format!(
                "backend {} decodes {required}-state streams, not {n_states}",
                backend.name()
            )));
        }
        if !backend_available(backend) {
            return Err(Error::invalid(format!(
                "backend {} is not available on this host",
                backend.name()
            )));
        }
        // The SIMD paths guard their unsafe gathers by falling back to
        // the scalar loop if the fused table ever failed to span the
        // slot space; when a backend was *forced*, that silent fallback
        // would defeat the differential seam — error loudly instead.
        if table.dec_table().len() != SCALE as usize {
            return Err(Error::invalid("fused decode table does not span the slot space"));
        }
    }
    match backend {
        Backend::Scalar => multistate::decode_multistate_scalar(bytes, count, table, n_states),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability (runtime CPUID) was checked above for
        // both SIMD backends; that is their only precondition.
        Backend::Sse41 => unsafe { x86::decode4(bytes, count, table) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — avx2 verified present by backend_available.
        Backend::Avx2 => unsafe { x86::decode8(bytes, count, table) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => unreachable!("non-scalar backends are rejected above on non-x86_64"),
    }
}

/// `pshufb` control table for the movemask-driven refill, indexed by
/// the `need-refill` lane mask `m` (4 bits, so 16 entries — the AVX2
/// path indexes it twice, once per 128-bit half).
///
/// For each 32-bit lane `j` with bit `j` set in `m`, the control routes
/// source bytes `2k` and `2k+1` (the `k`-th 16-bit stream word, where
/// `k` is the number of refilling lanes below `j`) into the lane's low
/// half and zeroes its high half; lanes not refilling are fully zeroed
/// (`0x80` control bytes) and the subsequent blend keeps their state.
/// This reproduces the wire contract that refills consume the shared
/// cursor in state order, `2·popcount(m)` bytes per round.
#[cfg(any(target_arch = "x86_64", test))]
const fn refill_shuffles() -> [[u8; 16]; 16] {
    let mut table = [[0x80u8; 16]; 16];
    let mut m = 0usize;
    while m < 16 {
        let mut next_word = 0u8;
        let mut lane = 0usize;
        while lane < 4 {
            if m & (1 << lane) != 0 {
                table[m][4 * lane] = 2 * next_word;
                table[m][4 * lane + 1] = 2 * next_word + 1;
                next_word += 1;
            }
            lane += 1;
        }
        m += 1;
    }
    table
}

/// See [`refill_shuffles`].
#[cfg(any(target_arch = "x86_64", test))]
static REFILL_SHUF: [[u8; 16]; 16] = refill_shuffles();

#[cfg(target_arch = "x86_64")]
mod x86 {
    #![deny(unsafe_op_in_unsafe_fn)]

    use core::arch::x86_64::*;

    use crate::error::Result;
    use crate::rans::freq::{FreqTable, SCALE, SCALE_BITS};
    use crate::rans::multistate::{decode_n, finish, read_states, scalar_rounds};

    use super::REFILL_SHUF;

    /// Decode a 4-state stream, vectorizing one round (4 symbols) per
    /// iteration with SSE4.1.
    ///
    /// # Safety
    ///
    /// The caller must have verified at runtime that this CPU supports
    /// `sse4.1` (e.g. via `is_x86_feature_detected!`).
    #[target_feature(enable = "sse4.1")]
    pub(super) unsafe fn decode4(
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
    ) -> Result<Vec<u32>> {
        let dec = table.dec_table();
        // Gather-index invariant: the unsafe loads below index the table
        // with `state & (SCALE−1)`, so it must span the full slot space.
        // Every FreqTable constructor upholds this, but the SIMD path
        // must not lean on a debug-only assert — if a future constructor
        // ever breaks the invariant, take the bounds-checked scalar loop
        // instead of reading out of bounds in release builds.
        if dec.len() != SCALE as usize {
            return decode_n::<4>(bytes, count, table);
        }
        let mut states = read_states::<4>(bytes)?;
        let mut pos = 16usize;
        // Same untrusted-header reservation cap as the scalar decoder.
        let mut out: Vec<u32> = Vec::with_capacity(count.min(1 << 20));
        let entries = dec.as_ptr().cast::<u64>();

        let full_rounds = count / 4;
        let mut rounds_done = 0usize;

        // SAFETY: `states` is a `[u32; 4]` — exactly the 16 bytes an
        // unaligned vector load reads.
        let mut sv = unsafe { _mm_loadu_si128(states.as_ptr().cast()) };
        let slot_mask = _mm_set1_epi32((SCALE - 1) as i32);
        let low16 = _mm_set1_epi32(0xFFFF);
        let zero = _mm_setzero_si128();

        // One round's refill consumes at most 2 bytes per state; run the
        // vector loop only while that worst case (8 bytes) is in bounds
        // and let the scalar finisher handle the stream tail.
        while rounds_done < full_rounds && pos + 8 <= bytes.len() {
            // Stage 1: gather the four fused 8-byte DecEntry slots.
            let slots = _mm_and_si128(sv, slot_mask);
            let mut idx = [0u32; 4];
            // SAFETY: `idx` is a `[u32; 4]` — exactly 16 writable bytes.
            unsafe { _mm_storeu_si128(idx.as_mut_ptr().cast(), slots) };
            // SAFETY: every index is `state & (SCALE−1) < SCALE` and the
            // fused table holds exactly SCALE 8-byte entries (checked on
            // entry), all bytes initialized (DecEntry's explicit zero
            // padding) — so the four u64 loads are in bounds and read
            // only initialized memory.
            let (e0, e1, e2, e3) = unsafe {
                (
                    *entries.add(idx[0] as usize),
                    *entries.add(idx[1] as usize),
                    *entries.add(idx[2] as usize),
                    *entries.add(idx[3] as usize),
                )
            };
            // Pack into vectors: lane order [e0, e1] / [e2, e3].
            let lo = _mm_set_epi64x(e1 as i64, e0 as i64);
            let hi = _mm_set_epi64x(e3 as i64, e2 as i64);
            // Split each entry into its dword halves (little-endian
            // DecEntry layout): sf = sym | freq << 16, bp = bias | 0.
            let sf = _mm_castps_si128(_mm_shuffle_ps::<0b10_00_10_00>(
                _mm_castsi128_ps(lo),
                _mm_castsi128_ps(hi),
            ));
            let bp = _mm_castps_si128(_mm_shuffle_ps::<0b11_01_11_01>(
                _mm_castsi128_ps(lo),
                _mm_castsi128_ps(hi),
            ));
            let freq = _mm_srli_epi32::<16>(sf);
            let sym = _mm_and_si128(sf, low16);
            let bias = _mm_and_si128(bp, low16);

            // Stage 2: four independent transitions in one packed
            // multiply — state ← freq · (state >> SCALE_BITS) + bias.
            let shifted = _mm_srli_epi32::<{ SCALE_BITS as i32 }>(sv);
            sv = _mm_add_epi32(_mm_mullo_epi32(freq, shifted), bias);

            // Stage 3: movemask-driven refill of states below 2^16.
            let need = _mm_cmpeq_epi32(_mm_srli_epi32::<16>(sv), zero);
            let m = _mm_movemask_ps(_mm_castsi128_ps(need)) as usize;
            // SAFETY: the loop guard holds pos + 8 <= bytes.len(), so
            // the 8-byte word load is in bounds.
            let words_raw = unsafe { _mm_loadl_epi64(bytes.as_ptr().add(pos).cast()) };
            // SAFETY: `m` is a 4-bit movemask (< 16) indexing the
            // 16-entry control table; each entry is 16 bytes.
            let ctrl = unsafe { _mm_loadu_si128(REFILL_SHUF[m].as_ptr().cast()) };
            let words = _mm_shuffle_epi8(words_raw, ctrl);
            let refilled = _mm_or_si128(_mm_slli_epi32::<16>(sv), words);
            sv = _mm_blendv_epi8(sv, refilled, need);
            pos += 2 * m.count_ones() as usize;

            // Emit the round's symbols in state order (the schedule).
            let mut sy = [0u32; 4];
            // SAFETY: `sy` is a `[u32; 4]` — exactly 16 writable bytes.
            unsafe { _mm_storeu_si128(sy.as_mut_ptr().cast(), sym) };
            out.extend_from_slice(&sy);
            rounds_done += 1;
        }

        // SAFETY: `states` is a `[u32; 4]` — exactly 16 writable bytes.
        unsafe { _mm_storeu_si128(states.as_mut_ptr().cast(), sv) };
        // Remaining rounds, tail symbols, and all validation run through
        // the scalar helpers — shared code, shared failure behavior.
        let remaining = full_rounds - rounds_done;
        scalar_rounds::<4>(bytes, &mut pos, &mut states, &mut out, remaining, dec)?;
        finish::<4>(bytes, &mut pos, &mut states, &mut out, count % 4, dec)?;
        Ok(out)
    }

    /// Decode an 8-state stream, vectorizing one round (8 symbols) per
    /// iteration with AVX2.
    ///
    /// # Safety
    ///
    /// The caller must have verified at runtime that this CPU supports
    /// `avx2` (e.g. via `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn decode8(
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
    ) -> Result<Vec<u32>> {
        let dec = table.dec_table();
        // Same release-mode gather-index guard as `decode4` above.
        if dec.len() != SCALE as usize {
            return decode_n::<8>(bytes, count, table);
        }
        let mut states = read_states::<8>(bytes)?;
        let mut pos = 32usize;
        let mut out: Vec<u32> = Vec::with_capacity(count.min(1 << 20));
        let base = dec.as_ptr().cast::<i32>();

        let full_rounds = count / 8;
        let mut rounds_done = 0usize;

        // SAFETY: `states` is a `[u32; 8]` — exactly the 32 bytes an
        // unaligned vector load reads.
        let mut sv = unsafe { _mm256_loadu_si256(states.as_ptr().cast()) };
        let slot_mask = _mm256_set1_epi32((SCALE - 1) as i32);
        let low16 = _mm256_set1_epi32(0xFFFF);
        let zero = _mm256_setzero_si256();

        // Worst-case refill per round is 2 bytes × 8 states = 16 bytes.
        while rounds_done < full_rounds && pos + 16 <= bytes.len() {
            // Stage 1: two dword gathers fetch both halves of all eight
            // fused entries (base + slot·8 → sym | freq << 16, and
            // base + slot·8 + 4 → bias; padding is zero).
            let slots = _mm256_and_si256(sv, slot_mask);
            // SAFETY: every gathered dword lies inside entry
            // `slot < SCALE` of the fused table (length checked on
            // entry, 8 bytes per entry, all bytes initialized), so the
            // gather at byte offset slot·8 is in bounds.
            let sf = unsafe { _mm256_i32gather_epi32::<8>(base, slots) };
            // SAFETY: as above for the entry's second dword at byte
            // offset slot·8 + 4.
            let bp = unsafe { _mm256_i32gather_epi32::<8>(base.add(1), slots) };
            let freq = _mm256_srli_epi32::<16>(sf);
            let sym = _mm256_and_si256(sf, low16);
            let bias = _mm256_and_si256(bp, low16);

            // Stage 2: eight transitions in one packed multiply.
            let shifted = _mm256_srli_epi32::<{ SCALE_BITS as i32 }>(sv);
            sv = _mm256_add_epi32(_mm256_mullo_epi32(freq, shifted), bias);

            // Stage 3: refill, split into the two 128-bit halves so the
            // 16-entry shuffle table serves both; the upper half's word
            // load starts after the bytes the lower half consumes,
            // preserving the state-order wire contract.
            let need = _mm256_cmpeq_epi32(_mm256_srli_epi32::<16>(sv), zero);
            let m = _mm256_movemask_ps(_mm256_castsi256_ps(need)) as usize;
            let m_lo = m & 0xF;
            let m_hi = m >> 4;
            let lo_bytes = 2 * m_lo.count_ones() as usize;
            // SAFETY: the loop guard holds pos + 16 <= bytes.len(), so
            // the lower half's 8-byte word load is in bounds.
            let w_lo = unsafe { _mm_loadl_epi64(bytes.as_ptr().add(pos).cast()) };
            // SAFETY: lo_bytes ≤ 8 and pos + 16 <= bytes.len(), so the
            // upper half's 8-byte load at pos + lo_bytes is in bounds.
            let w_hi = unsafe { _mm_loadl_epi64(bytes.as_ptr().add(pos + lo_bytes).cast()) };
            // SAFETY: `m_lo` is a 4-bit mask (< 16) indexing the
            // 16-entry control table; each entry is 16 bytes.
            let ctrl_lo = unsafe { _mm_loadu_si128(REFILL_SHUF[m_lo].as_ptr().cast()) };
            // SAFETY: as above for `m_hi` (< 16).
            let ctrl_hi = unsafe { _mm_loadu_si128(REFILL_SHUF[m_hi].as_ptr().cast()) };
            let words =
                _mm256_set_m128i(_mm_shuffle_epi8(w_hi, ctrl_hi), _mm_shuffle_epi8(w_lo, ctrl_lo));
            let refilled = _mm256_or_si256(_mm256_slli_epi32::<16>(sv), words);
            sv = _mm256_blendv_epi8(sv, refilled, need);
            pos += 2 * m.count_ones() as usize;

            let mut sy = [0u32; 8];
            // SAFETY: `sy` is a `[u32; 8]` — exactly 32 writable bytes.
            unsafe { _mm256_storeu_si256(sy.as_mut_ptr().cast(), sym) };
            out.extend_from_slice(&sy);
            rounds_done += 1;
        }

        // SAFETY: `states` is a `[u32; 8]` — exactly 32 writable bytes.
        unsafe { _mm256_storeu_si256(states.as_mut_ptr().cast(), sv) };
        let remaining = full_rounds - rounds_done;
        scalar_rounds::<8>(bytes, &mut pos, &mut states, &mut out, remaining, dec)?;
        finish::<8>(bytes, &mut pos, &mut states, &mut out, count % 8, dec)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rans::multistate::{decode_multistate, encode_multistate};
    use crate::util::prng::Rng;

    fn sample(seed: u64, len: usize, alphabet: usize) -> (Vec<u32>, FreqTable) {
        let mut rng = Rng::new(seed);
        let symbols: Vec<u32> = (0..len).map(|_| rng.zipf(alphabet, 1.2) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, alphabet);
        (symbols, table)
    }

    /// The invariant behind every `REFILL_SHUF[m]` unsafe index and the
    /// movemask-driven byte routing: lane `j` refilling receives the
    /// `k`-th stream word (k = refilling lanes below j), everything
    /// else is zeroed, and exactly `2·popcount(m)` source bytes are
    /// referenced.
    #[test]
    fn refill_shuffle_table_routes_words_in_state_order() {
        assert_eq!(REFILL_SHUF.len(), 16);
        for (m, ctrl) in REFILL_SHUF.iter().enumerate() {
            let mut next_word = 0u8;
            for lane in 0..4 {
                let b = &ctrl[4 * lane..4 * lane + 4];
                if m & (1 << lane) != 0 {
                    assert_eq!(b[0], 2 * next_word, "m={m} lane={lane}");
                    assert_eq!(b[1], 2 * next_word + 1, "m={m} lane={lane}");
                    assert_eq!(&b[2..], &[0x80, 0x80], "m={m} lane={lane}");
                    next_word += 1;
                } else {
                    assert_eq!(b, &[0x80; 4], "m={m} lane={lane}");
                }
            }
            assert_eq!(next_word as u32, (m as u32).count_ones(), "m={m}");
            // Every referenced source byte is within the words actually
            // consumed this round.
            for &c in ctrl.iter().filter(|&&c| c & 0x80 == 0) {
                assert!(c < 2 * next_word, "m={m} control byte {c}");
            }
        }
    }

    /// The gather-index invariant the SIMD loads rely on: the fused
    /// table spans the full masked slot space for any valid table.
    #[test]
    fn dec_table_spans_full_slot_space() {
        for alphabet in [1usize, 2, 100, 4096] {
            let symbols: Vec<u32> = (0..alphabet as u32).collect();
            let table = FreqTable::from_symbols(&symbols, alphabet);
            assert_eq!(table.dec_table().len(), crate::rans::freq::SCALE as usize);
        }
    }

    #[test]
    fn backend_metadata_is_consistent() {
        assert!(backend_available(Backend::Scalar));
        assert_eq!(Backend::Scalar.states(), None);
        assert_eq!(Backend::Sse41.states(), Some(4));
        assert_eq!(Backend::Avx2.states(), Some(8));
        assert_eq!(Backend::Sse41.name(), "sse4.1");
        // The auto dispatcher only ever picks available backends whose
        // width matches the stream.
        for n in [1usize, 2, 4, 8] {
            let b = backend_for(n);
            assert!(backend_available(b), "n={n}");
            if let Some(w) = b.states() {
                assert_eq!(w, n);
            }
        }
        // Scalar-only state counts never dispatch to SIMD.
        assert_eq!(backend_for(1), Backend::Scalar);
        assert_eq!(backend_for(2), Backend::Scalar);
    }

    #[test]
    fn forcing_mismatched_or_missing_backends_errors() {
        let (symbols, table) = sample(1, 64, 16);
        let bytes = encode_multistate(&symbols, &table, 4).unwrap();
        // Width mismatch is always an error, available or not.
        assert!(decode_multistate_with(&bytes, 64, &table, 8, Backend::Sse41).is_err());
        assert!(decode_multistate_with(&bytes, 64, &table, 4, Backend::Avx2).is_err());
        // Scalar backend accepts every supported count.
        assert_eq!(
            decode_multistate_with(&bytes, 64, &table, 4, Backend::Scalar).unwrap(),
            symbols
        );
        // An unavailable SIMD backend is a loud error, not a silent
        // scalar fallback.
        if !backend_available(Backend::Sse41) {
            assert!(decode_multistate_with(&bytes, 64, &table, 4, Backend::Sse41).is_err());
        }
        if !backend_available(Backend::Avx2) {
            let b8 = encode_multistate(&symbols, &table, 8).unwrap();
            assert!(decode_multistate_with(&b8, 64, &table, 8, Backend::Avx2).is_err());
        }
    }

    /// Every available backend must agree with the scalar loop across
    /// lengths straddling the round-robin and refill-guard edges.
    #[test]
    fn simd_matches_scalar_on_valid_streams() {
        for (states, backend) in [(4usize, Backend::Sse41), (8, Backend::Avx2)] {
            for len in [0usize, 1, 3, 7, 8, 9, 31, 1000, 20_011] {
                for alphabet in [2usize, 64, 300] {
                    let seed = 41 ^ ((len as u64) << 4) ^ states as u64;
                    let (symbols, table) = sample(seed, len, alphabet);
                    let bytes = encode_multistate(&symbols, &table, states).unwrap();
                    let scalar =
                        decode_multistate_with(&bytes, len, &table, states, Backend::Scalar)
                            .unwrap();
                    assert_eq!(scalar, symbols);
                    // The auto path must agree whatever it dispatched to.
                    let auto = decode_multistate(&bytes, len, &table, states).unwrap();
                    assert_eq!(auto, scalar, "auto states={states} len={len}");
                    if backend_available(backend) {
                        let forced =
                            decode_multistate_with(&bytes, len, &table, states, backend).unwrap();
                        assert_eq!(forced, scalar, "forced states={states} len={len}");
                    }
                }
            }
        }
    }

    /// Corrupt streams: SIMD and scalar must agree on acceptance, and
    /// on the decoded symbols whenever both accept.
    #[test]
    fn simd_matches_scalar_on_corrupt_streams() {
        let mut rng = Rng::new(0x51D);
        for (states, backend) in [(4usize, Backend::Sse41), (8, Backend::Avx2)] {
            if !backend_available(backend) {
                continue;
            }
            let (symbols, table) = sample(7 + states as u64, 5000, 40);
            let bytes = encode_multistate(&symbols, &table, states).unwrap();
            for _ in 0..200 {
                let mut bad = bytes.clone();
                match rng.below(3) {
                    0 => {
                        let i = rng.below_usize(bad.len());
                        bad[i] ^= 1 << rng.below(8);
                    }
                    1 => {
                        let cut = rng.below_usize(bad.len());
                        bad.truncate(cut);
                    }
                    _ => {
                        bad.push(rng.next_u64() as u8);
                    }
                }
                let scalar =
                    decode_multistate_with(&bad, symbols.len(), &table, states, Backend::Scalar);
                let simd = decode_multistate_with(&bad, symbols.len(), &table, states, backend);
                match (scalar, simd) {
                    (Ok(a), Ok(b)) => assert_eq!(a, b, "states={states}"),
                    (Err(_), Err(_)) => {}
                    (a, b) => panic!(
                        "backends disagree on acceptance (states={states}): \
                         scalar ok={} simd ok={}",
                        a.is_ok(),
                        b.is_ok()
                    ),
                }
            }
        }
    }
}
