//! AArch64 NEON backend for the v2 multi-state gather decode.
//!
//! The edge half of the paper's split-computing pipeline runs on
//! aarch64 devices (phones, Jetsons, Pis), so this is the ISA where the
//! SIMD decode actually earns its keep. The rounds mirror the x86 paths
//! in [`super::simd`] stage for stage — gather, packed transition,
//! movemask-driven refill — with the NEON translations:
//!
//! * **Gather.** AArch64 has no gather instruction, so the fused 8-byte
//!   [`DecEntry`] slots are fetched exactly like the SSE4.1 path: `N`
//!   scalar `u64` loads packed into vectors
//!   (`vcreate_u64`/`vcombine_u64`), then one `vuzp1q`/`vuzp2q` pair
//!   per four entries splits them into the `sym | freq << 16` and
//!   `bias` dword vectors (the role `shufps` plays on x86).
//! * **Transition.** `state ← freq · (state >> SCALE_BITS) + bias` is a
//!   single fused `vmlaq_u32` per four states.
//! * **Refill.** NEON has no `movmskps`, so the 4-bit `need-refill`
//!   lane mask is rebuilt by narrowing the `state < 2^16` compare to
//!   16-bit lanes (`vmovn_u32`) and picking one bit per lane out of the
//!   resulting `u64`. The mask then drives the *same* 16-entry
//!   [`REFILL_SHUF`] control table as x86: `vqtbl1q_u8` zeroes any
//!   destination byte whose control byte is out of range, which is
//!   precisely `pshufb`'s high-bit convention, so one table serves both
//!   ISAs. A `vbslq_u32` blend merges the routed stream words into the
//!   refilling lanes and the shared cursor advances `2·popcount` bytes
//!   in state order — the wire contract.
//!
//! The 8-state round runs the same stages over two `uint32x4_t` halves,
//! the upper half's stream words starting after the bytes the lower
//! half consumes (mirroring the AVX2 split-half refill).
//!
//! The vector loop keeps the worst-case refill for one round (`2·N`
//! bytes) in bounds and hands the stream tail, the `count mod N`
//! symbols, and all end-of-stream validation to the shared scalar
//! helpers [`multistate::scalar_rounds`] / [`multistate::finish`] — so
//! the NEON path cannot diverge from the scalar loop on acceptance, by
//! construction. Symbol-identity of the vector rounds is pinned by the
//! differential fuzz wall and the committed golden vectors, which CI
//! replays on aarch64 under QEMU with the backend force-pinned.
//!
//! NEON (ASIMD) is mandatory in the AArch64 ABI, so availability is the
//! compile target itself — no runtime feature detection.
//!
//! [`DecEntry`]: super::symbol::DecEntry
//! [`REFILL_SHUF`]: super::simd::REFILL_SHUF
//! [`multistate::scalar_rounds`]: super::multistate::scalar_rounds
//! [`multistate::finish`]: super::multistate::finish

use crate::error::Result;

use super::freq::FreqTable;
use super::simd::{unavailable_error, width_error, Backend, DecodeBackend};

/// The NEON 4-/8-state gather decoder as a
/// [`DecodeBackend`](super::simd::DecodeBackend). Available exactly on
/// aarch64 builds (NEON is baseline there); covers both SIMD stream
/// widths, unlike the one-width x86 backends.
pub(crate) struct NeonBackend;

impl DecodeBackend for NeonBackend {
    fn id(&self) -> Backend {
        Backend::Neon
    }

    fn available(&self) -> bool {
        cfg!(target_arch = "aarch64")
    }

    fn supports_states(&self, n_states: usize) -> bool {
        matches!(n_states, 4 | 8)
    }

    fn decode(
        &self,
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
        n_states: usize,
    ) -> Result<Vec<u32>> {
        if !self.supports_states(n_states) {
            return Err(width_error(self.id(), n_states));
        }
        if !self.available() {
            return Err(unavailable_error(self.id()));
        }
        #[cfg(target_arch = "aarch64")]
        {
            // SAFETY: NEON is part of the aarch64 baseline ABI, so on
            // this compile target the target-feature precondition of
            // the decode functions always holds.
            if n_states == 4 {
                unsafe { aarch64::decode4(bytes, count, table) }
            } else {
                unsafe { aarch64::decode8(bytes, count, table) }
            }
        }
        #[cfg(not(target_arch = "aarch64"))]
        {
            let _ = (bytes, count, table);
            unreachable!("neon reported available on a non-aarch64 build")
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod aarch64 {
    #![deny(unsafe_op_in_unsafe_fn)]

    use core::arch::aarch64::*;

    use crate::error::Result;
    use crate::rans::freq::{FreqTable, SCALE, SCALE_BITS};
    use crate::rans::multistate::{decode_n, finish, read_states, scalar_rounds};
    use crate::rans::simd::REFILL_SHUF;

    /// Gather four fused 8-byte entries by the slot indices in `slots`
    /// and split them into `(sym | freq << 16, bias)` dword vectors —
    /// the scalar-load-and-pack shape the SSE4.1 path uses, since
    /// AArch64 has no gather instruction.
    ///
    /// # Safety
    ///
    /// Every lane of `slots` must be `< SCALE` and `entries` must point
    /// at `SCALE` fully initialized 8-byte entries ([`DecEntry`]'s
    /// explicit zero padding makes the raw `u64` reads defined).
    ///
    /// [`DecEntry`]: crate::rans::symbol::DecEntry
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn gather_entries(entries: *const u64, slots: uint32x4_t) -> (uint32x4_t, uint32x4_t) {
        let mut idx = [0u32; 4];
        // SAFETY: `idx` is a `[u32; 4]` — exactly 16 writable bytes.
        unsafe { vst1q_u32(idx.as_mut_ptr(), slots) };
        // SAFETY: caller guarantees every index is `< SCALE` and the
        // table holds exactly SCALE initialized 8-byte entries, so the
        // four u64 loads are in bounds and read initialized memory.
        let (e0, e1, e2, e3) = unsafe {
            (
                *entries.add(idx[0] as usize),
                *entries.add(idx[1] as usize),
                *entries.add(idx[2] as usize),
                *entries.add(idx[3] as usize),
            )
        };
        // Pack into vectors (lane order [e0, e1] / [e2, e3]) and
        // de-interleave the entry dwords: even dwords carry
        // sym | freq << 16, odd dwords carry bias (little-endian
        // DecEntry layout).
        let lo = vreinterpretq_u32_u64(vcombine_u64(vcreate_u64(e0), vcreate_u64(e1)));
        let hi = vreinterpretq_u32_u64(vcombine_u64(vcreate_u64(e2), vcreate_u64(e3)));
        (vuzp1q_u32(lo, hi), vuzp2q_u32(lo, hi))
    }

    /// One packed transition over four states:
    /// `state ← freq · (state >> SCALE_BITS) + bias`. Returns the new
    /// states and the decoded symbols (in state order).
    #[inline]
    #[target_feature(enable = "neon")]
    fn transition(sv: uint32x4_t, sf: uint32x4_t, bp: uint32x4_t) -> (uint32x4_t, uint32x4_t) {
        let low16 = vdupq_n_u32(0xFFFF);
        let freq = vshrq_n_u32::<16>(sf);
        let sym = vandq_u32(sf, low16);
        let bias = vandq_u32(bp, low16);
        let shifted = vshrq_n_u32::<{ SCALE_BITS as i32 }>(sv);
        // vmlaq_u32(a, b, c) = a + b·c; the product provably fits
        // 32 bits (see the scalar decoder).
        (vmlaq_u32(bias, freq, shifted), sym)
    }

    /// Refill the lanes of `sv` that dropped below `2^16` with 16-bit
    /// stream words from `src`, routed in state order through
    /// [`REFILL_SHUF`]. Returns the refilled states and the number of
    /// stream bytes consumed (`2·popcount` of the lane mask).
    ///
    /// # Safety
    ///
    /// At least 8 bytes must be readable at `src` (one round's
    /// worst-case refill for four states).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn refill(sv: uint32x4_t, src: *const u8) -> (uint32x4_t, usize) {
        let need = vceqq_u32(vshrq_n_u32::<16>(sv), vdupq_n_u32(0));
        // Movemask emulation: narrow the all-ones/all-zeros compare to
        // 16-bit lanes, view the result as one u64 (lane j occupies
        // bits 16j..16j+16), and pick one bit per lane.
        let bits = vget_lane_u64::<0>(vreinterpret_u64_u16(vmovn_u32(need)));
        let m =
            ((bits & 1) | ((bits >> 15) & 2) | ((bits >> 30) & 4) | ((bits >> 45) & 8)) as usize;
        // SAFETY: caller guarantees 8 readable bytes at `src`.
        let words_raw = vcombine_u8(unsafe { vld1_u8(src) }, vdup_n_u8(0));
        // `m` is a 4-bit mask, so the control-table index is in bounds;
        // SAFETY (load): each control entry is a 16-byte array.
        let ctrl = unsafe { vld1q_u8(REFILL_SHUF[m].as_ptr()) };
        // vqtbl1q_u8 zeroes destination bytes whose control byte is out
        // of range — pshufb's 0x80 convention, so the shared table
        // routes the next popcount(m) words to their lanes unchanged.
        let words = vreinterpretq_u32_u8(vqtbl1q_u8(words_raw, ctrl));
        let refilled = vorrq_u32(vshlq_n_u32::<16>(sv), words);
        // vbslq_u32(mask, a, b) = (mask & a) | (!mask & b): keep
        // non-refilling lanes as they were.
        (vbslq_u32(need, refilled, sv), 2 * m.count_ones() as usize)
    }

    /// Decode a 4-state stream, vectorizing one round (4 symbols) per
    /// iteration with NEON.
    ///
    /// # Safety
    ///
    /// The build target must support NEON — always true on aarch64,
    /// where this module is compiled.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn decode4(
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
    ) -> Result<Vec<u32>> {
        let dec = table.dec_table();
        // Same release-mode gather-index guard as the x86 paths: the
        // raw u64 entry loads index with `state & (SCALE−1)`, so the
        // fused table must span the full slot space — take the
        // bounds-checked scalar loop otherwise.
        if dec.len() != SCALE as usize {
            return decode_n::<4>(bytes, count, table);
        }
        let mut states = read_states::<4>(bytes)?;
        let mut pos = 16usize;
        // Same untrusted-header reservation cap as the scalar decoder.
        let mut out: Vec<u32> = Vec::with_capacity(count.min(1 << 20));
        let entries = dec.as_ptr().cast::<u64>();

        let full_rounds = count / 4;
        let mut rounds_done = 0usize;

        // SAFETY: `states` is a `[u32; 4]` — exactly 16 readable bytes.
        let mut sv = unsafe { vld1q_u32(states.as_ptr()) };
        let slot_mask = vdupq_n_u32(SCALE - 1);

        // One round's refill consumes at most 2 bytes per state; run
        // the vector loop only while that worst case (8 bytes) is in
        // bounds and let the scalar finisher handle the stream tail.
        while rounds_done < full_rounds && pos + 8 <= bytes.len() {
            let slots = vandq_u32(sv, slot_mask);
            // SAFETY: every slot lane is masked `< SCALE` and the table
            // spans SCALE entries (checked on entry).
            let (sf, bp) = unsafe { gather_entries(entries, slots) };
            let (next, sym) = transition(sv, sf, bp);
            // SAFETY: the loop guard holds pos + 8 <= bytes.len().
            let (refilled, consumed) = unsafe { refill(next, bytes.as_ptr().add(pos)) };
            sv = refilled;
            pos += consumed;

            // Emit the round's symbols in state order (the schedule).
            let mut sy = [0u32; 4];
            // SAFETY: `sy` is a `[u32; 4]` — exactly 16 writable bytes.
            unsafe { vst1q_u32(sy.as_mut_ptr(), sym) };
            out.extend_from_slice(&sy);
            rounds_done += 1;
        }

        // SAFETY: `states` is a `[u32; 4]` — exactly 16 writable bytes.
        unsafe { vst1q_u32(states.as_mut_ptr(), sv) };
        // Remaining rounds, tail symbols, and all validation run
        // through the scalar helpers — shared code, shared failure
        // behavior.
        let remaining = full_rounds - rounds_done;
        scalar_rounds::<4>(bytes, &mut pos, &mut states, &mut out, remaining, dec)?;
        finish::<4>(bytes, &mut pos, &mut states, &mut out, count % 4, dec)?;
        Ok(out)
    }

    /// Decode an 8-state stream, vectorizing one round (8 symbols) per
    /// iteration as two four-lane NEON halves.
    ///
    /// # Safety
    ///
    /// The build target must support NEON — always true on aarch64,
    /// where this module is compiled.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn decode8(
        bytes: &[u8],
        count: usize,
        table: &FreqTable,
    ) -> Result<Vec<u32>> {
        let dec = table.dec_table();
        // Same release-mode gather-index guard as `decode4` above.
        if dec.len() != SCALE as usize {
            return decode_n::<8>(bytes, count, table);
        }
        let mut states = read_states::<8>(bytes)?;
        let mut pos = 32usize;
        let mut out: Vec<u32> = Vec::with_capacity(count.min(1 << 20));
        let entries = dec.as_ptr().cast::<u64>();

        let full_rounds = count / 8;
        let mut rounds_done = 0usize;

        // SAFETY: `states` is a `[u32; 8]` — two in-bounds 16-byte
        // loads.
        let mut sv_lo = unsafe { vld1q_u32(states.as_ptr()) };
        // SAFETY: as above, upper four states.
        let mut sv_hi = unsafe { vld1q_u32(states.as_ptr().add(4)) };
        let slot_mask = vdupq_n_u32(SCALE - 1);

        // Worst-case refill per round is 2 bytes × 8 states = 16 bytes.
        while rounds_done < full_rounds && pos + 16 <= bytes.len() {
            let slots_lo = vandq_u32(sv_lo, slot_mask);
            let slots_hi = vandq_u32(sv_hi, slot_mask);
            // SAFETY: every slot lane is masked `< SCALE` and the table
            // spans SCALE entries (checked on entry).
            let (sf_lo, bp_lo) = unsafe { gather_entries(entries, slots_lo) };
            // SAFETY: as above.
            let (sf_hi, bp_hi) = unsafe { gather_entries(entries, slots_hi) };
            let (next_lo, sym_lo) = transition(sv_lo, sf_lo, bp_lo);
            let (next_hi, sym_hi) = transition(sv_hi, sf_hi, bp_hi);

            // Split-half refill: the lower states consume first, the
            // upper half's stream words start after them — preserving
            // the state-order wire contract.
            // SAFETY: the loop guard holds pos + 16 <= bytes.len(), so
            // the lower half's 8-byte window is in bounds.
            let (refilled_lo, lo_bytes) = unsafe { refill(next_lo, bytes.as_ptr().add(pos)) };
            // SAFETY: lo_bytes ≤ 8 and pos + 16 <= bytes.len(), so the
            // upper half's 8-byte window at pos + lo_bytes is in
            // bounds.
            let (refilled_hi, hi_bytes) =
                unsafe { refill(next_hi, bytes.as_ptr().add(pos + lo_bytes)) };
            sv_lo = refilled_lo;
            sv_hi = refilled_hi;
            pos += lo_bytes + hi_bytes;

            let mut sy = [0u32; 8];
            // SAFETY: `sy` is a `[u32; 8]` — two in-bounds 16-byte
            // stores.
            unsafe { vst1q_u32(sy.as_mut_ptr(), sym_lo) };
            // SAFETY: as above, upper four symbols.
            unsafe { vst1q_u32(sy.as_mut_ptr().add(4), sym_hi) };
            out.extend_from_slice(&sy);
            rounds_done += 1;
        }

        // SAFETY: `states` is a `[u32; 8]` — two in-bounds 16-byte
        // stores.
        unsafe { vst1q_u32(states.as_mut_ptr(), sv_lo) };
        // SAFETY: as above, upper four states.
        unsafe { vst1q_u32(states.as_mut_ptr().add(4), sv_hi) };
        let remaining = full_rounds - rounds_done;
        scalar_rounds::<8>(bytes, &mut pos, &mut states, &mut out, remaining, dec)?;
        finish::<8>(bytes, &mut pos, &mut states, &mut out, count % 8, dec)?;
        Ok(out)
    }
}
