//! Range Asymmetric Numeral Systems (rANS) entropy codec.
//!
//! Implements the coding process of §2.1 of the paper (Eqs. 2–4):
//! a single integer state `s` absorbs symbols according to their
//! frequencies `f(x)` and cumulative frequencies `F(x)`, with
//! renormalization keeping the state inside a fixed interval so integer
//! divisions/moduli stay exact.
//!
//! Layout of this module:
//! * [`freq`] — empirical frequency tables, normalization to a power-of-two
//!   total, CDFs, O(1) slot→symbol lookup, and compact serialization (the
//!   side information transmitted with each bitstream).
//! * [`encode`] / [`decode`] — the scalar codec. Symbols are encoded in
//!   reverse so the decoder runs forward over the byte stream.
//! * [`interleaved`] — N independent lanes over one symbol stream; the
//!   CPU analogue of the paper's GPU-parallel rANS (DietGPU-style), used
//!   by the pipeline for sub-millisecond encode/decode.
//!
//! The state is 32-bit with 16-bit renormalization windows
//! (`state ∈ [2^16, 2^32)`), the layout used by production rANS coders;
//! the paper's `n`-bit precision corresponds to [`freq::SCALE_BITS`].

pub mod decode;
pub mod encode;
pub mod freq;
pub mod interleaved;

pub use decode::decode;
pub use encode::encode;
pub use freq::FreqTable;
pub use interleaved::{decode_interleaved, encode_interleaved, InterleavedStream};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// End-to-end roundtrip across distribution shapes: uniform, skewed,
    /// degenerate, tiny alphabet — the regimes called out in the paper's
    /// "Key Observations".
    #[test]
    fn roundtrip_distribution_zoo() {
        let mut rng = Rng::new(2024);
        let cases: Vec<(usize, Box<dyn FnMut(&mut Rng) -> u32>)> = vec![
            (16, Box::new(|r: &mut Rng| r.below(16) as u32)), // uniform
            (64, Box::new(|r: &mut Rng| r.zipf(64, 1.3) as u32)), // skewed
            (2, Box::new(|r: &mut Rng| (r.next_f64() < 0.95) as u32)), // binary skew
            (256, Box::new(|r: &mut Rng| r.zipf(256, 2.0) as u32)), // heavy skew
        ];
        for (alphabet, mut gen) in cases {
            for len in [0usize, 1, 7, 1000, 40_000] {
                let symbols: Vec<u32> = (0..len).map(|_| gen(&mut rng)).collect();
                let table = FreqTable::from_symbols(&symbols, alphabet);
                let bytes = encode(&symbols, &table).unwrap();
                let back = decode(&bytes, symbols.len(), &table).unwrap();
                assert_eq!(back, symbols, "alphabet {alphabet} len {len}");
            }
        }
    }

    /// Compressed size must approach the entropy bound for skewed data
    /// (within a few percent, as rANS promises).
    #[test]
    fn size_close_to_entropy_bound() {
        let mut rng = Rng::new(7);
        let symbols: Vec<u32> = (0..100_000).map(|_| rng.zipf(32, 1.5) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, 32);
        let bytes = encode(&symbols, &table).unwrap();
        let freqs = crate::util::stats::histogram(&symbols, 32);
        let bound_bytes = crate::util::stats::entropy_bits(&freqs) / 8.0;
        let actual = bytes.len() as f64;
        assert!(
            actual < bound_bytes * 1.05 + 16.0,
            "actual {actual} vs bound {bound_bytes}"
        );
    }
}
