//! Range Asymmetric Numeral Systems (rANS) entropy codec.
//!
//! Implements the coding process of §2.1 of the paper (Eqs. 2–4):
//! a single integer state `s` absorbs symbols according to their
//! frequencies `f(x)` and cumulative frequencies `F(x)`, with
//! renormalization keeping the state inside a fixed interval so integer
//! divisions/moduli stay exact.
//!
//! Layout of this module:
//! * [`freq`] — empirical frequency tables, normalization to a power-of-two
//!   total, CDFs, O(1) slot→symbol lookup, and compact serialization (the
//!   side information transmitted with each bitstream).
//! * [`symbol`] — precomputed per-symbol coding metadata: exact
//!   reciprocal-multiply division for the encoder ([`symbol::EncSymbol`])
//!   and the fused `slot → {sym, freq, bias}` decode entry
//!   ([`symbol::DecEntry`]). Built once per table, cached inside
//!   [`FreqTable`], shared by every path that holds the table.
//! * [`encode`] / [`decode`] — the scalar codec, division-free: no
//!   integer `div`/`mod` on the encode path, one table load per decoded
//!   symbol, single-branch renormalization on both sides. Symbols are
//!   encoded in reverse so the decoder runs forward over the byte
//!   stream. The wire format is byte-identical to the textbook div/mod
//!   formulation (pinned by `rust/tests/golden_vectors.rs`).
//! * [`interleaved`] — N independent lanes over one symbol stream; the
//!   CPU analogue of the paper's GPU-parallel rANS (DietGPU-style), used
//!   by the pipeline for sub-millisecond encode/decode. Carries the
//!   stream-layout flag ([`interleaved::StreamLayout`]) that gates the
//!   v2 multi-state format.
//! * [`multistate`] — N interleaved coder states *within* one lane
//!   (rans_static-style round-robin), breaking the decoder's serial
//!   dependency chain so the out-of-order core overlaps 2–8 independent
//!   multiply/refill chains (the v2 lane payload format).
//! * [`simd`] — data-level parallelism over those independent states:
//!   one vectorized decode round per iteration, with every
//!   implementation behind the cross-ISA [`simd::DecodeBackend`] trait
//!   seam (SSE4.1 for 4-state lanes and AVX2 for 8-state lanes on
//!   x86_64), runtime-dispatched with the const-generic scalar loop as
//!   the portable fallback and a validated `RANS_SC_FORCE_BACKEND`
//!   process-wide override. No wire-format change; pinned
//!   symbol-identical to the scalar path by
//!   `rust/tests/rans_differential.rs`.
//! * [`neon`] — the aarch64 backend behind the same seam: NEON 4- and
//!   8-state decode rounds (scalar-load-and-pack gathers, `vmlaq_u32`
//!   transitions, `vqtbl1q_u8` refill routing through the shared
//!   control table), covering the ISA the paper's edge devices actually
//!   run.
//!
//! The state is 32-bit with 16-bit renormalization windows
//! (`state ∈ [2^16, 2^32)`), the layout used by production rANS coders;
//! the paper's `n`-bit precision corresponds to [`freq::SCALE_BITS`].

pub mod decode;
pub mod encode;
pub mod freq;
pub mod interleaved;
pub mod multistate;
pub mod neon;
pub mod simd;
pub mod symbol;

pub use decode::decode;
pub use encode::encode;
pub use freq::FreqTable;
pub use interleaved::{
    decode_interleaved, encode_interleaved, encode_interleaved_with_layout, InterleavedStream,
    StreamLayout,
};
pub use multistate::{decode_multistate, decode_multistate_scalar, encode_multistate};
pub use symbol::{DecEntry, EncSymbol};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// End-to-end roundtrip across distribution shapes: uniform, skewed,
    /// degenerate, tiny alphabet — the regimes called out in the paper's
    /// "Key Observations".
    #[test]
    fn roundtrip_distribution_zoo() {
        let mut rng = Rng::new(2024);
        let cases: Vec<(usize, Box<dyn FnMut(&mut Rng) -> u32>)> = vec![
            (16, Box::new(|r: &mut Rng| r.below(16) as u32)), // uniform
            (64, Box::new(|r: &mut Rng| r.zipf(64, 1.3) as u32)), // skewed
            (2, Box::new(|r: &mut Rng| (r.next_f64() < 0.95) as u32)), // binary skew
            (256, Box::new(|r: &mut Rng| r.zipf(256, 2.0) as u32)), // heavy skew
        ];
        for (alphabet, mut gen) in cases {
            for len in [0usize, 1, 7, 1000, 40_000] {
                let symbols: Vec<u32> = (0..len).map(|_| gen(&mut rng)).collect();
                let table = FreqTable::from_symbols(&symbols, alphabet);
                let bytes = encode(&symbols, &table).unwrap();
                let back = decode(&bytes, symbols.len(), &table).unwrap();
                assert_eq!(back, symbols, "alphabet {alphabet} len {len}");
            }
        }
    }

    /// The division-free encoder must emit exactly the bytes of the
    /// textbook div/mod formulation of Eq. (2) — the wire-format
    /// contract the reciprocal strength-reduction promises. (The
    /// committed golden vectors in `rust/tests/golden_vectors.rs` pin
    /// the same property against fixed cross-language vectors.)
    #[test]
    fn division_free_encoder_matches_textbook_reference() {
        fn encode_reference(symbols: &[u32], table: &FreqTable) -> Vec<u8> {
            use crate::rans::freq::SCALE_BITS;
            let mut state: u32 = encode::STATE_LOWER;
            let mut rev_bytes: Vec<u8> = Vec::new();
            for &sym in symbols.iter().rev() {
                let f = table.freq_of(sym);
                let x_max = (((encode::STATE_LOWER >> SCALE_BITS) as u64) << 16) * f as u64;
                while state as u64 >= x_max {
                    rev_bytes.push((state >> 8) as u8);
                    rev_bytes.push(state as u8);
                    state >>= 16;
                }
                state = ((state / f) << SCALE_BITS) + (state % f) + table.cdf_of(sym);
            }
            let mut out = Vec::with_capacity(4 + rev_bytes.len());
            out.extend_from_slice(&state.to_le_bytes());
            out.extend(rev_bytes.iter().rev());
            out
        }

        let mut rng = Rng::new(0x5EED);
        for (alphabet, s) in [(2usize, 0.5), (40, 1.1), (300, 1.6)] {
            for len in [1usize, 50, 20_000] {
                let symbols: Vec<u32> =
                    (0..len).map(|_| rng.zipf(alphabet, s) as u32).collect();
                let table = FreqTable::from_symbols(&symbols, alphabet);
                assert_eq!(
                    encode(&symbols, &table).unwrap(),
                    encode_reference(&symbols, &table),
                    "alphabet {alphabet} len {len}"
                );
            }
        }
        // Maximal alphabet (one slot per symbol, every freq == 1).
        let symbols: Vec<u32> =
            (0..30_000).map(|_| rng.below(4096) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, 4096);
        assert_eq!(
            encode(&symbols, &table).unwrap(),
            encode_reference(&symbols, &table)
        );
        // Skew hard enough that one symbol's frequency lands in
        // (2048, 4096) — the regime where a 32-bit reciprocal would be
        // inexact and only the 33-bit scheme stays byte-identical.
        let symbols: Vec<u32> =
            (0..50_000).map(|_| u32::from(rng.next_f64() < 0.03)).collect();
        let table = FreqTable::from_symbols(&symbols, 2);
        assert!(table.freq_of(0) > 2048 && table.freq_of(0) < 4096);
        assert_eq!(
            encode(&symbols, &table).unwrap(),
            encode_reference(&symbols, &table)
        );
    }

    /// Compressed size must approach the entropy bound for skewed data
    /// (within a few percent, as rANS promises).
    #[test]
    fn size_close_to_entropy_bound() {
        let mut rng = Rng::new(7);
        let symbols: Vec<u32> = (0..100_000).map(|_| rng.zipf(32, 1.5) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, 32);
        let bytes = encode(&symbols, &table).unwrap();
        let freqs = crate::util::stats::histogram(&symbols, 32);
        let bound_bytes = crate::util::stats::entropy_bits(&freqs) / 8.0;
        let actual = bytes.len() as f64;
        assert!(
            actual < bound_bytes * 1.05 + 16.0,
            "actual {actual} vs bound {bound_bytes}"
        );
    }
}
