//! Intra-lane multi-state interleaved rANS (v2 streams).
//!
//! The scalar codec ([`super::encode`]/[`super::decode`]) is
//! division-free with a fused one-load decode table, so its remaining
//! bottleneck is the *serial dependency chain on the single coder
//! state*: each decoded symbol's multiply + refill must retire before
//! the next table load can issue. This module breaks that chain the way
//! ryg/rans_static's interleaved variants (and DietGPU across warps) do:
//! `N` **independent** rANS states inside one lane, assigned round-robin
//! over the symbol stream, so an out-of-order core overlaps `N`
//! multiply/refill chains.
//!
//! # Stream layout (one lane payload)
//!
//! ```text
//! [u32 LE state_0][u32 LE state_1] … [u32 LE state_{N−1}]
//! [renormalization bytes, decode order]
//! ```
//!
//! Exactly the scalar layout with `N` final-state words instead of one;
//! an `N = 1` stream is **byte-identical** to a scalar stream (and is
//! routed through the scalar codec).
//!
//! # Interleaving discipline (the wire contract)
//!
//! * Symbol `i` of the lane is coded by state `i mod N` — pure position
//!   arithmetic, so the decoder reconstructs the schedule with no extra
//!   metadata.
//! * All `N` states share **one** byte stream (rans_static's
//!   single-stream interleaving). The encoder walks symbols in reverse
//!   (`i = count−1 … 0`), and whichever state renormalizes pushes its
//!   16-bit flush (hi byte, then lo byte) onto one shared
//!   last-in-first-out buffer; after all symbols, the `N` final states
//!   are written little-endian in state order `0 … N−1`, followed by the
//!   shared buffer reversed wholesale. The decoder reads the `N` state
//!   words, then consumes symbols *forward* with the same `i mod N`
//!   schedule, refilling from the stream front. Because decode steps run
//!   in exactly the opposite order of encode steps — the same schedule,
//!   mirrored — each refill meets precisely the bytes its encode-side
//!   flush produced, regardless of which state flushed when. This is the
//!   identical argument that makes the scalar LIFO→FIFO arrangement
//!   work; the schedule just has `N` interleaved chains now.
//! * Renormalization stays single-branch per symbol on both sides (the
//!   scalar bounds are per-state properties and `N` states don't
//!   interact arithmetically).
//!
//! The exact byte order is replicated by the independent Python oracle
//! (`rust/tests/golden/gen_golden.py`, `rans_encode_multistate`) and
//! pinned by committed golden vectors.
//!
//! # Decoder structure
//!
//! The hot loop handles `⌊count/N⌋` full rounds with the per-round body
//! unrolled over a const-generic `N`: all `N` fused table loads issue
//! first (each depends only on its own state from the previous round),
//! then the `N` independent transitions, then the refills in symbol
//! order (refills share the stream cursor, a short add-compare chain the
//! core hides under the multiplies). The `count mod N` tail runs
//! states `0 … (count mod N) − 1` one final time.

use crate::error::{Error, Result};

use super::decode::decode;
use super::encode::{encode, STATE_LOWER};
use super::freq::{FreqTable, SCALE, SCALE_BITS};
use super::symbol::DecEntry;

/// Maximum states per lane accepted by encoder and decoder. Four
/// independent chains saturate the multiply ports of a scalar core
/// (mirrors rans_static's 4-way interleave); eight exist for the AVX2
/// gather decoder ([`super::simd`]), which retires one full round per
/// 256-bit vector and so keeps paying past the scalar sweet spot.
pub const MAX_STATES: usize = 8;

/// True iff `n` is a state count this module codes: 1, 2, 4, or 8.
/// (Other values are representable in the header but deliberately
/// unsupported — round-robin over a non-power-of-two adds a modulo to
/// the hot loop, and power-of-two counts above 8 exceed both the scalar
/// register budget and the widest SIMD path.)
pub fn supported_states(n: usize) -> bool {
    matches!(n, 1 | 2 | 4 | 8)
}

/// Encode `symbols` with `n_states` interleaved rANS states
/// (round-robin: symbol `i` → state `i mod n_states`).
///
/// `n_states == 1` produces (and routes through) the scalar encoder —
/// byte-identical output. Errors on unsupported state counts, symbols
/// outside the table's alphabet, or zero-frequency symbols.
pub fn encode_multistate(symbols: &[u32], table: &FreqTable, n_states: usize) -> Result<Vec<u8>> {
    match n_states {
        1 => encode(symbols, table),
        2 => encode_n::<2>(symbols, table),
        4 => encode_n::<4>(symbols, table),
        8 => encode_n::<8>(symbols, table),
        n => Err(Error::invalid(format!(
            "unsupported states-per-lane {n} (supported: 1, 2, 4, 8)"
        ))),
    }
}

/// Decode exactly `count` symbols from an `n_states`-state stream
/// produced by [`encode_multistate`] with the same table and count.
///
/// Every state is checked against the initial-state invariant after the
/// last symbol, and the stream must be fully consumed — truncation,
/// trailing bytes, or a forged state word all yield `Error::Corrupt`.
///
/// For 4- and 8-state streams this dispatches through the cross-ISA
/// backend seam ([`super::simd::backend_for`]) to the SIMD gather
/// decoder the host supports — SSE4.1 / AVX2 on x86_64 (detected at
/// runtime), NEON on aarch64 — falling back to the const-generic
/// scalar loop otherwise, and honoring the validated
/// `RANS_SC_FORCE_BACKEND` override. All paths are symbol-identical on
/// valid streams and agree on rejection of corrupt ones (pinned by
/// `rust/tests/rans_differential.rs`).
pub fn decode_multistate(
    bytes: &[u8],
    count: usize,
    table: &FreqTable,
    n_states: usize,
) -> Result<Vec<u32>> {
    super::simd::dispatch_decode(bytes, count, table, n_states)
}

/// [`decode_multistate`] pinned to the portable scalar loop for every
/// state count — the reference the SIMD paths are differentially fuzzed
/// against (and the benchmark baseline their speedup is measured from).
pub fn decode_multistate_scalar(
    bytes: &[u8],
    count: usize,
    table: &FreqTable,
    n_states: usize,
) -> Result<Vec<u32>> {
    match n_states {
        1 => decode(bytes, count, table),
        2 => decode_n::<2>(bytes, count, table),
        4 => decode_n::<4>(bytes, count, table),
        8 => decode_n::<8>(bytes, count, table),
        n => Err(Error::corrupt(format!(
            "unsupported states-per-lane {n} (supported: 1, 2, 4, 8)"
        ))),
    }
}

fn encode_n<const N: usize>(symbols: &[u32], table: &FreqTable) -> Result<Vec<u8>> {
    let m = table.alphabet() as u32;
    let enc = table.enc_table();
    let mut states = [STATE_LOWER; N];
    // Flushes from all states merge into one reverse-order buffer.
    let mut rev_bytes: Vec<u8> = Vec::with_capacity(symbols.len());

    for (i, &sym) in symbols.iter().enumerate().rev() {
        if sym >= m {
            return Err(Error::codec(format!("symbol {sym} outside alphabet {m}")));
        }
        let e = &enc[sym as usize];
        if e.freq == 0 {
            return Err(Error::codec(format!("symbol {sym} has zero frequency")));
        }
        let s = &mut states[i % N];
        // Renormalize (at most once — the scalar bound is per-state).
        if *s as u64 >= e.x_max {
            rev_bytes.push((*s >> 8) as u8);
            rev_bytes.push(*s as u8);
            *s >>= 16;
        }
        let q = e.quotient(*s);
        *s = *s + e.bias + q * e.cmpl_freq;
    }

    let mut out = Vec::with_capacity(4 * N + rev_bytes.len());
    for s in states {
        out.extend_from_slice(&s.to_le_bytes());
    }
    out.extend(rev_bytes.iter().rev());
    Ok(out)
}

/// Read the `N` little-endian final-state words that lead a lane
/// payload. Shared by the scalar and SIMD decoders.
pub(crate) fn read_states<const N: usize>(bytes: &[u8]) -> Result<[u32; N]> {
    if bytes.len() < 4 * N {
        return Err(Error::corrupt(format!(
            "multi-state rANS stream shorter than {N} state words"
        )));
    }
    let mut states = [0u32; N];
    for (j, s) in states.iter_mut().enumerate() {
        *s = u32::from_le_bytes([
            bytes[4 * j],
            bytes[4 * j + 1],
            bytes[4 * j + 2],
            bytes[4 * j + 3],
        ]);
    }
    Ok(states)
}

/// Run `rounds` full scalar decode rounds (`N` symbols each) from the
/// current `states`/`pos`. This is the portable hot loop — and also the
/// SIMD decoders' finisher: when the vector loop runs out of guaranteed
/// refill bytes it hands `states`, `pos`, and the remaining round count
/// here, so the two paths are identical by construction from that point
/// on.
pub(crate) fn scalar_rounds<const N: usize>(
    bytes: &[u8],
    pos: &mut usize,
    states: &mut [u32; N],
    out: &mut Vec<u32>,
    rounds: usize,
    dec: &[DecEntry],
) -> Result<()> {
    let mask = SCALE - 1;
    for _ in 0..rounds {
        // N independent loads, then N independent transitions: the only
        // cross-state dependency is the refill cursor below.
        let entries: [DecEntry; N] = std::array::from_fn(|j| dec[(states[j] & mask) as usize]);
        for (s, e) in states.iter_mut().zip(&entries) {
            *s = (e.freq as u32) * (*s >> SCALE_BITS) + e.bias as u32;
        }
        // Refills consume the shared cursor in symbol order (state 0
        // first — the exact mirror of the encoder's schedule).
        for (s, e) in states.iter_mut().zip(&entries) {
            if *s < STATE_LOWER {
                if *pos + 2 > bytes.len() {
                    return Err(Error::corrupt(
                        "multi-state rANS stream truncated mid-renormalization",
                    ));
                }
                let lo = u16::from_le_bytes([bytes[*pos], bytes[*pos + 1]]) as u32;
                *s = (*s << 16) | lo;
                *pos += 2;
            }
            out.push(e.sym as u32);
        }
    }
    Ok(())
}

/// Decode the tail round (`tail < N` symbols on states `0 … tail−1`)
/// and run the end-of-stream checks every decoder shares: all `N`
/// states back at the initial-state invariant, stream fully consumed.
pub(crate) fn finish<const N: usize>(
    bytes: &[u8],
    pos: &mut usize,
    states: &mut [u32; N],
    out: &mut Vec<u32>,
    tail: usize,
    dec: &[DecEntry],
) -> Result<()> {
    debug_assert!(tail < N);
    let mask = SCALE - 1;
    for s in states.iter_mut().take(tail) {
        let e = dec[(*s & mask) as usize];
        *s = (e.freq as u32) * (*s >> SCALE_BITS) + e.bias as u32;
        if *s < STATE_LOWER {
            if *pos + 2 > bytes.len() {
                return Err(Error::corrupt(
                    "multi-state rANS stream truncated mid-renormalization",
                ));
            }
            let lo = u16::from_le_bytes([bytes[*pos], bytes[*pos + 1]]) as u32;
            *s = (*s << 16) | lo;
            *pos += 2;
        }
        out.push(e.sym as u32);
    }

    for (j, &s) in states.iter().enumerate() {
        if s != STATE_LOWER {
            return Err(Error::corrupt(format!(
                "multi-state rANS final state {j} is {s:#x}, expected {STATE_LOWER:#x}"
            )));
        }
    }
    if *pos != bytes.len() {
        return Err(Error::corrupt(format!(
            "multi-state rANS stream has {} trailing bytes",
            bytes.len() - *pos
        )));
    }
    Ok(())
}

pub(crate) fn decode_n<const N: usize>(
    bytes: &[u8],
    count: usize,
    table: &FreqTable,
) -> Result<Vec<u32>> {
    let mut states = read_states::<N>(bytes)?;
    let mut pos = 4 * N;
    // `count` comes from untrusted headers; cap the reservation like the
    // scalar decoder so a forged count fails in the loop, not the
    // allocator.
    let mut out: Vec<u32> = Vec::with_capacity(count.min(1 << 20));
    let dec = table.dec_table();
    scalar_rounds::<N>(bytes, &mut pos, &mut states, &mut out, count / N, dec)?;
    finish::<N>(bytes, &mut pos, &mut states, &mut out, count % N, dec)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn sample(seed: u64, len: usize, alphabet: usize) -> (Vec<u32>, FreqTable) {
        let mut rng = Rng::new(seed);
        let symbols: Vec<u32> = (0..len).map(|_| rng.zipf(alphabet, 1.2) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, alphabet);
        (symbols, table)
    }

    #[test]
    fn roundtrip_states_by_len_by_alphabet() {
        for (alphabet, seed) in [(2usize, 1u64), (16, 2), (64, 3), (256, 4)] {
            // Lengths straddling the round-robin edges: count < N,
            // count == N, count % N ∈ {0, 1, N−1} for every N up to 8.
            for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 1000, 40_003] {
                let (symbols, table) = sample(seed ^ (len as u64) << 8, len, alphabet);
                for n in [1usize, 2, 4, 8] {
                    let bytes = encode_multistate(&symbols, &table, n).unwrap();
                    let back = decode_multistate(&bytes, len, &table, n).unwrap();
                    assert_eq!(back, symbols, "alphabet {alphabet} len {len} states {n}");
                    // The scalar loop must agree regardless of which
                    // backend decode_multistate dispatched to.
                    let scalar = decode_multistate_scalar(&bytes, len, &table, n).unwrap();
                    assert_eq!(scalar, symbols, "scalar alphabet {alphabet} len {len} states {n}");
                }
            }
        }
    }

    #[test]
    fn single_state_is_byte_identical_to_scalar() {
        let (symbols, table) = sample(5, 20_000, 64);
        assert_eq!(
            encode_multistate(&symbols, &table, 1).unwrap(),
            crate::rans::encode(&symbols, &table).unwrap()
        );
    }

    #[test]
    fn empty_stream_is_state_words_only() {
        let table = FreqTable::from_symbols(&[], 8);
        for n in [1usize, 2, 4, 8] {
            let bytes = encode_multistate(&[], &table, n).unwrap();
            assert_eq!(bytes.len(), 4 * n, "states {n}");
            // All state words are the initial state.
            for j in 0..n {
                assert_eq!(
                    u32::from_le_bytes(bytes[4 * j..4 * j + 4].try_into().unwrap()),
                    crate::rans::encode::STATE_LOWER
                );
            }
            assert_eq!(decode_multistate(&bytes, 0, &table, n).unwrap(), Vec::<u32>::new());
        }
    }

    #[test]
    fn fewer_symbols_than_states() {
        // Idle states must still flush/verify their untouched initial
        // state words.
        let (symbols, table) = sample(6, 3, 8);
        for n in [4usize, 8] {
            let bytes = encode_multistate(&symbols, &table, n).unwrap();
            assert_eq!(decode_multistate(&bytes, 3, &table, n).unwrap(), symbols, "states {n}");
        }
    }

    #[test]
    fn unsupported_state_counts_rejected() {
        let (symbols, table) = sample(7, 100, 8);
        for n in [0usize, 3, 5, 6, 7, MAX_STATES + 1, 1000] {
            assert!(encode_multistate(&symbols, &table, n).is_err(), "encode n={n}");
            let bytes = encode_multistate(&symbols, &table, 2).unwrap();
            assert!(decode_multistate(&bytes, 100, &table, n).is_err(), "decode n={n}");
        }
        assert!(supported_states(1) && supported_states(2));
        assert!(supported_states(4) && supported_states(8));
        assert!(!supported_states(0) && !supported_states(3));
        assert!(!supported_states(5) && !supported_states(6) && !supported_states(7));
        assert!(!supported_states(9));
    }

    #[test]
    fn compressed_size_overhead_is_state_words_only() {
        // Extra states cost ~4 bytes each (one more final-state word),
        // not a payload blow-up.
        let (symbols, table) = sample(8, 100_000, 32);
        let one = encode_multistate(&symbols, &table, 1).unwrap().len();
        let four = encode_multistate(&symbols, &table, 4).unwrap().len();
        assert!(four < one + 4 * 16, "1-state {one}B vs 4-state {four}B");
    }

    #[test]
    fn truncation_detected() {
        let (symbols, table) = sample(9, 5000, 40);
        for n in [2usize, 4, 8] {
            let bytes = encode_multistate(&symbols, &table, n).unwrap();
            // Shorter than the state-word block.
            assert!(decode_multistate(&bytes[..4 * n - 1], symbols.len(), &table, n).is_err());
            // Drop trailing payload: truncation or final-state check fires.
            let cut = &bytes[..bytes.len() - 2];
            assert!(decode_multistate(cut, symbols.len(), &table, n).is_err());
        }
    }

    #[test]
    fn trailing_garbage_detected() {
        let (symbols, table) = sample(10, 1000, 16);
        for n in [2usize, 4, 8] {
            let mut bytes = encode_multistate(&symbols, &table, n).unwrap();
            bytes.extend_from_slice(&[0xAB, 0xCD]);
            assert!(decode_multistate(&bytes, symbols.len(), &table, n).is_err());
        }
    }

    #[test]
    fn wrong_count_detected() {
        let (symbols, table) = sample(11, 1000, 16);
        for n in [2usize, 4, 8] {
            let bytes = encode_multistate(&symbols, &table, n).unwrap();
            assert!(decode_multistate(&bytes, symbols.len() - 1, &table, n).is_err());
        }
    }

    #[test]
    fn wrong_state_count_cross_decode_fails_or_differs() {
        // Decoding an N-state stream as N'-state must never silently
        // yield the original symbols.
        let (symbols, table) = sample(12, 2000, 32);
        for right in [4usize, 8] {
            let bytes = encode_multistate(&symbols, &table, right).unwrap();
            for wrong in [1usize, 2, 4, 8] {
                if wrong == right {
                    continue;
                }
                match decode_multistate(&bytes, symbols.len(), &table, wrong) {
                    Err(_) => {}
                    Ok(decoded) => assert_ne!(decoded, symbols, "right={right} wrong={wrong}"),
                }
            }
        }
    }

    #[test]
    fn bitflip_detected_or_changes_output() {
        let (symbols, table) = sample(13, 2000, 32);
        for n in [2usize, 4, 8] {
            let mut bytes = encode_multistate(&symbols, &table, n).unwrap();
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x40;
            match decode_multistate(&bytes, symbols.len(), &table, n) {
                Err(_) => {}
                Ok(decoded) => assert_ne!(decoded, symbols),
            }
        }
    }

    #[test]
    fn rejects_out_of_alphabet_and_zero_freq() {
        let table = FreqTable::from_symbols(&[0, 0, 1], 3);
        for n in [2usize, 4, 8] {
            assert!(encode_multistate(&[3], &table, n).is_err());
            assert!(encode_multistate(&[2], &table, n).is_err());
        }
    }

    /// The N-state encoder must match a direct transcription of the
    /// textbook div/mod recurrence run with the same schedule — the
    /// same wire-format contract the scalar core carries, per state.
    #[test]
    fn multistate_encoder_matches_textbook_reference() {
        fn encode_reference(symbols: &[u32], table: &FreqTable, n: usize) -> Vec<u8> {
            let mut states = vec![STATE_LOWER; n];
            let mut rev: Vec<u8> = Vec::new();
            for (i, &sym) in symbols.iter().enumerate().rev() {
                let f = table.freq_of(sym);
                let x_max = (((STATE_LOWER >> SCALE_BITS) as u64) << 16) * f as u64;
                let s = &mut states[i % n];
                while (*s as u64) >= x_max {
                    rev.push((*s >> 8) as u8);
                    rev.push(*s as u8);
                    *s >>= 16;
                }
                *s = ((*s / f) << SCALE_BITS) + (*s % f) + table.cdf_of(sym);
            }
            let mut out = Vec::with_capacity(4 * n + rev.len());
            for s in states {
                out.extend_from_slice(&s.to_le_bytes());
            }
            out.extend(rev.iter().rev());
            out
        }

        let mut rng = Rng::new(0x5EED2);
        for (alphabet, s) in [(2usize, 0.5), (40, 1.1), (300, 1.6)] {
            for len in [1usize, 5, 50, 20_000] {
                let symbols: Vec<u32> =
                    (0..len).map(|_| rng.zipf(alphabet, s) as u32).collect();
                let table = FreqTable::from_symbols(&symbols, alphabet);
                for n in [2usize, 4, 8] {
                    assert_eq!(
                        encode_multistate(&symbols, &table, n).unwrap(),
                        encode_reference(&symbols, &table, n),
                        "alphabet {alphabet} len {len} states {n}"
                    );
                }
            }
        }
    }
}
