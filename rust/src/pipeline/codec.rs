//! Pipeline compress/decompress drivers.
//!
//! These entry points are thin wrappers over the process-wide
//! [`crate::engine::Engine::shared`] instance; callers that want their
//! own pool size, the chunked v2 container, plan caching, or a forced
//! decode-threading mode construct an [`crate::engine::Engine`]
//! directly.
//!
//! The primary surface is **dtype-generic and zero-copy**:
//! [`compress_tensor`] borrows any [`TensorRef`] (f32/f16/bf16) and
//! quantizes with conversion fused into the load, and
//! [`decompress_into`] dequantizes into a caller-owned [`TensorMut`] of
//! the container's dtype. The `&[f32]` forms ([`compress`],
//! [`decompress`]) remain as shims with byte-identical output, and
//! decode entry points carry no `parallel: bool` — decode threading is
//! the engine's config-carried setting
//! ([`crate::engine::EngineConfig::decode_parallel`]).

use crate::error::Result;
use crate::quant::{self, QuantParams};
use crate::tensor::{Dtype, TensorMut, TensorRef};

pub use crate::rans::interleaved::StreamLayout;

/// How the reshape dimension `N` is chosen.
#[derive(Debug, Clone)]
pub enum ReshapeStrategy {
    /// Use a caller-supplied `N` (must divide `T`). The coordinator uses
    /// this with its per-(T, Q) plan cache so Algorithm 1 runs once per
    /// tensor shape, not per request.
    Fixed(usize),
    /// Run Algorithm 1 inline (paper defaults).
    Optimize,
    /// Skip reshaping: `N = T`, `K = 1` (ablation baseline).
    Flat,
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// AIQ bit-width `Q`.
    pub q: u8,
    /// rANS lanes.
    pub lanes: usize,
    /// Thread the lanes on **encode**. (Decode threading has no
    /// per-call knob; it is carried by the engine —
    /// [`crate::engine::EngineConfig::decode_parallel`].)
    pub parallel: bool,
    /// Reshape selection.
    pub reshape: ReshapeStrategy,
    /// Per-lane stream layout: v1 scalar lanes (the compatibility
    /// default — byte-identical to the pre-v2 wire format) or v2
    /// multi-state lanes ([`StreamLayout::MultiState`], ILP decode).
    /// Applies to the v1 container's interleaved payload; the chunked
    /// v2 container keeps scalar per-chunk streams regardless.
    pub layout: StreamLayout,
}

impl PipelineConfig {
    /// Paper-default configuration at bit-width `q`.
    ///
    /// Lane *threading* adapts to the machine via the engine's pool-size
    /// heuristic (see [`default_parallelism`]): on a single-core host
    /// lanes are encoded serially; the stream format stays multi-lane
    /// either way, so a parallel decoder can still fan out.
    pub fn paper(q: u8) -> Self {
        PipelineConfig {
            q,
            lanes: 8,
            parallel: default_parallelism(),
            reshape: ReshapeStrategy::Optimize,
            layout: StreamLayout::V1,
        }
    }

    /// This configuration with `states` interleaved rANS states per
    /// lane (v2 streams; `states == 1` keeps the v1 layout).
    pub fn with_states(self, states: usize) -> Self {
        let layout =
            if states <= 1 { StreamLayout::V1 } else { StreamLayout::MultiState(states) };
        PipelineConfig { layout, ..self }
    }
}

/// Whether threading the rANS lanes helps on this host.
///
/// Delegates to the engine's pool-size heuristic
/// ([`crate::engine::Engine::auto_pool_size`]) so the serial/parallel
/// decision lives in exactly one place: an auto-sized engine gets one
/// worker on a single-core host and runs everything serially. The
/// query itself does not instantiate the shared engine — config
/// construction must stay side-effect-free.
pub fn default_parallelism() -> bool {
    crate::engine::Engine::auto_pool_size() > 1
}

/// Statistics from one compression call (feeds telemetry and benches).
#[derive(Debug, Clone)]
pub struct CompressStats {
    /// Selected reshape rows `N`.
    pub n_rows: usize,
    /// Columns `K`.
    pub n_cols: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// Entropy of `D` in bits/symbol.
    pub entropy: f64,
    /// Total container bytes.
    pub total_bytes: usize,
    /// Bytes of rANS payload only.
    pub payload_bytes: usize,
    /// Bytes of side information (frequency table + header).
    pub side_info_bytes: usize,
    /// Candidates evaluated if Algorithm 1 ran (0 for Fixed/Flat).
    pub reshape_evaluated: usize,
}

/// What one [`decompress_into`] call decoded: the element count and
/// dtype sniffed from the container header, plus the quantization
/// parameters the reconstruction used.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeInfo {
    /// Decoded (and written) element count.
    pub elements: usize,
    /// The container's dtype tag — also the output buffer's dtype.
    pub dtype: Dtype,
    /// Quantization parameters from the container header.
    pub params: QuantParams,
}

/// Compress pre-quantized symbols (hot path; see module docs). The
/// container is tagged `f32`; symbol producers for half-precision
/// models use [`crate::engine::Engine::compress_quantized_dtype`].
pub fn compress_quantized(
    symbols: &[u16],
    params: QuantParams,
    cfg: &PipelineConfig,
) -> Result<(Vec<u8>, CompressStats)> {
    crate::engine::Engine::shared().compress_quantized(symbols, params, cfg)
}

/// Compress a dtype-tagged tensor view (quantization inside). The
/// borrowed storage is traversed exactly twice — fused min/max fit,
/// then the divide-free quantize pass
/// ([`quant::fit_and_quantize_tensor`]) — converting f16/bf16 elements
/// to `f32` on load, with no intermediate `f32` `Vec` for any dtype.
pub fn compress_tensor(
    tensor: TensorRef<'_>,
    cfg: &PipelineConfig,
) -> Result<(Vec<u8>, CompressStats)> {
    crate::engine::Engine::shared().compress_tensor(tensor, cfg)
}

/// Compress an `f32` tensor — a thin shim over [`compress_tensor`]
/// with byte-identical output to every pre-dtype release.
pub fn compress(data: &[f32], cfg: &PipelineConfig) -> Result<(Vec<u8>, CompressStats)> {
    compress_tensor(TensorRef::from_f32(data), cfg)
}

/// Decompress to quantized symbols plus the quantization parameters
/// (cloud hot path — the tail artifact dequantizes on-device). Accepts
/// both the v1 and the chunked v2 container (magic-sniffed), in both
/// their f32 and dtype-tagged forms.
pub fn decompress_to_symbols(bytes: &[u8]) -> Result<(Vec<u16>, QuantParams)> {
    crate::engine::Engine::shared().decompress_to_symbols(bytes)
}

/// Decompress all the way to an `f32` vector, whatever the container's
/// dtype tag. For zero-copy decode into a caller buffer of the
/// container's own dtype, use [`decompress_into`].
pub fn decompress(bytes: &[u8]) -> Result<Vec<f32>> {
    let (symbols, params) = decompress_to_symbols(bytes)?;
    Ok(quant::dequantize(&symbols, &params))
}

/// Decompress straight into a caller-owned output buffer (zero-copy
/// decode). The buffer's dtype must match the container's dtype tag and
/// its capacity must cover the decoded element count; see
/// [`crate::engine::Engine::decompress_into`].
pub fn decompress_into(bytes: &[u8], out: TensorMut<'_>) -> Result<DecodeInfo> {
    crate::engine::Engine::shared().decompress_into(bytes, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn synth_if(seed: u64, c: usize, h: usize, w: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; c * h * w];
        for ch in 0..c {
            let act = rng.next_f64();
            for i in 0..h * w {
                if rng.next_f64() < 0.4 * act * 2.0 {
                    x[ch * h * w + i] = (rng.normal().abs() as f32) * (0.3 + act as f32);
                }
            }
        }
        x
    }

    #[test]
    fn roundtrip_symbol_exact() {
        // Quantized symbols must survive the pipeline bit-exactly.
        let data = synth_if(1, 32, 14, 14);
        for q in [2u8, 3, 4, 6, 8] {
            let cfg = PipelineConfig::paper(q);
            let params = QuantParams::fit(q, &data).unwrap();
            let symbols = quant::quantize(&data, &params);
            let (bytes, _) = compress_quantized(&symbols, params, &cfg).unwrap();
            let (back, back_params) = decompress_to_symbols(&bytes).unwrap();
            assert_eq!(back, symbols, "q={q}");
            assert_eq!(back_params, params);
        }
    }

    #[test]
    fn float_roundtrip_error_bounded() {
        let data = synth_if(2, 16, 8, 8);
        let cfg = PipelineConfig::paper(6);
        let (bytes, _) = compress(&data, &cfg).unwrap();
        let back = decompress(&bytes).unwrap();
        let params = QuantParams::fit(6, &data).unwrap();
        let tol = params.scale + 1e-6;
        for (a, b) in data.iter().zip(&back) {
            assert!((a - b).abs() <= tol);
        }
        // Exact zeros must reconstruct exactly (sparsity preservation).
        for (a, b) in data.iter().zip(&back) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            }
        }
    }

    #[test]
    fn compresses_sparse_features_hard() {
        let data = synth_if(3, 64, 14, 14);
        let raw = data.len() * 4;
        let (bytes, stats) = compress(&data, &PipelineConfig::paper(4)).unwrap();
        let ratio = raw as f64 / bytes.len() as f64;
        assert!(ratio > 4.0, "ratio {ratio:.2}");
        assert_eq!(stats.total_bytes, bytes.len());
        assert!(stats.payload_bytes < stats.total_bytes);
    }

    #[test]
    fn all_strategies_roundtrip() {
        let data = synth_if(4, 8, 16, 16);
        let t = data.len();
        for strat in [
            ReshapeStrategy::Optimize,
            ReshapeStrategy::Flat,
            ReshapeStrategy::Fixed(t / 16),
        ] {
            let cfg = PipelineConfig {
                q: 4,
                lanes: 4,
                parallel: false,
                reshape: strat.clone(),
                layout: StreamLayout::V1,
            };
            let (bytes, _) = compress(&data, &cfg).unwrap();
            let back = decompress(&bytes).unwrap();
            assert_eq!(back.len(), t, "{strat:?}");
        }
    }

    #[test]
    fn optimized_not_worse_than_flat() {
        let data = synth_if(5, 64, 14, 14);
        let opt = compress(&data, &PipelineConfig::paper(4)).unwrap().1;
        let flat = compress(
            &data,
            &PipelineConfig { reshape: ReshapeStrategy::Flat, ..PipelineConfig::paper(4) },
        )
        .unwrap()
        .1;
        assert!(
            opt.total_bytes <= flat.total_bytes,
            "optimize {} > flat {}",
            opt.total_bytes,
            flat.total_bytes
        );
    }

    #[test]
    fn invalid_fixed_n_rejected() {
        let data = synth_if(6, 4, 5, 5);
        let cfg = PipelineConfig {
            q: 4,
            lanes: 2,
            parallel: false,
            reshape: ReshapeStrategy::Fixed(7),
            layout: StreamLayout::V1,
        };
        assert!(compress(&data, &cfg).is_err());
    }

    #[test]
    fn empty_tensor_rejected() {
        assert!(compress(&[], &PipelineConfig::paper(4)).is_err());
    }

    /// v2 multi-state streams ride inside the same RSC1 container; the
    /// decoder needs no hint (the stream layout is self-describing, and
    /// 4/8-state payloads pick up the SIMD decode path transparently).
    #[test]
    fn multistate_roundtrip_symbol_exact() {
        let data = synth_if(9, 32, 14, 14);
        for q in [2u8, 4, 8] {
            for states in [2usize, 4, 8] {
                let cfg = PipelineConfig::paper(q).with_states(states);
                let params = QuantParams::fit(q, &data).unwrap();
                let symbols = quant::quantize(&data, &params);
                let (bytes, stats) = compress_quantized(&symbols, params, &cfg).unwrap();
                assert_eq!(&bytes[0..4], b"RSC1");
                assert_eq!(stats.total_bytes, bytes.len());
                let (back, back_params) = decompress_to_symbols(&bytes).unwrap();
                assert_eq!(back, symbols, "q={q} states={states}");
                assert_eq!(back_params, params);
            }
        }
    }

    #[test]
    fn with_states_folds_one_into_v1() {
        assert_eq!(PipelineConfig::paper(4).with_states(1).layout, StreamLayout::V1);
        assert_eq!(
            PipelineConfig::paper(4).with_states(4).layout,
            StreamLayout::MultiState(4)
        );
        // states == 1 must stay byte-identical to the v1 default.
        let data = synth_if(10, 8, 8, 8);
        let a = compress(&data, &PipelineConfig::paper(4)).unwrap().0;
        let b = compress(&data, &PipelineConfig::paper(4).with_states(1)).unwrap().0;
        assert_eq!(a, b);
    }

    #[test]
    fn f32_shim_is_byte_identical_to_tensor_entry_point() {
        let data = synth_if(11, 16, 8, 8);
        let cfg = PipelineConfig::paper(4);
        let (a, _) = compress(&data, &cfg).unwrap();
        let (b, _) = compress_tensor(TensorRef::from_f32(&data), &cfg).unwrap();
        assert_eq!(a, b);
        // Zero-copy decode into a caller buffer matches the Vec path.
        let via_vec = decompress(&a).unwrap();
        let mut buf = vec![0.0f32; data.len()];
        let info = decompress_into(&a, TensorMut::from_f32(&mut buf)).unwrap();
        assert_eq!(info.dtype, Dtype::F32);
        assert_eq!(info.elements, data.len());
        assert_eq!(buf, via_vec);
    }

    #[test]
    fn half_tensor_roundtrips_through_shared_engine() {
        use crate::tensor::half;
        let data = synth_if(12, 8, 8, 8);
        let f16: Vec<u16> = data.iter().map(|&x| half::f32_to_f16(x)).collect();
        let (bytes, _) =
            compress_tensor(TensorRef::from_f16_bits(&f16), &PipelineConfig::paper(6)).unwrap();
        let mut out = vec![0u16; f16.len()];
        let info = decompress_into(&bytes, TensorMut::from_f16_bits(&mut out)).unwrap();
        assert_eq!(info.dtype, Dtype::F16);
        // Exact zeros survive (sparsity preservation holds per dtype).
        for (a, b) in f16.iter().zip(&out) {
            if half::f16_to_f32(*a) == 0.0 {
                assert_eq!(half::f16_to_f32(*b), 0.0);
            }
        }
    }

    #[test]
    fn smaller_q_smaller_payload() {
        let data = synth_if(7, 32, 14, 14);
        let mut last = usize::MAX;
        for q in [8u8, 6, 4, 3] {
            let (bytes, _) = compress(&data, &PipelineConfig::paper(q)).unwrap();
            assert!(
                bytes.len() <= last,
                "q={q}: {} bytes > previous {last}",
                bytes.len()
            );
            last = bytes.len();
        }
    }

    #[test]
    fn stats_are_consistent() {
        let data = synth_if(8, 16, 14, 14);
        let (bytes, stats) = compress(&data, &PipelineConfig::paper(4)).unwrap();
        assert_eq!(stats.n_rows * stats.n_cols, data.len());
        assert_eq!(stats.total_bytes, bytes.len());
        assert_eq!(stats.side_info_bytes + stats.payload_bytes, stats.total_bytes);
        assert!(stats.reshape_evaluated > 0); // Optimize ran
    }
}
