//! Self-describing container for one compressed intermediate feature.
//!
//! Layout (all multi-byte integers varint unless noted):
//!
//! ```text
//! magic  "RSC1"                 4 bytes
//! version                       1 byte  (1 = f32, 2 = dtype-tagged)
//! q                             1 byte
//! dtype tag                     1 byte  (version 2 only; see Dtype::tag)
//! scale                         4 bytes f32 LE
//! zero                          varint (zigzag)
//! orig_len  T                   varint
//! n_rows    N                   varint
//! nnz                           varint
//! alphabet                      varint
//! freq table                    FreqTable::serialize
//! payload_len                   varint
//! payload (interleaved rANS)    payload_len bytes
//! crc32 of everything above     4 bytes LE
//! ```
//!
//! `K = T / N` is derived, not stored. The CRC turns any bitstream
//! corruption (including rANS streams that happen to decode) into a
//! clean [`Error::Corrupt`] instead of silent garbage at the tail model.
//!
//! **Dtype tagging.** `f32` tensors serialize as version 1 with no tag
//! byte, so every pre-dtype container stays byte-identical on the wire.
//! Half-precision tensors (f16/bf16 — the Llama2-style LM path) emit
//! version 2, which inserts a one-byte [`Dtype`] tag after `q`;
//! decoders sniff the version byte, so no caller-side knob exists.
//!
//! The payload is an interleaved rANS stream in either layout — v1
//! scalar lanes or v2 multi-state lanes (see
//! [`crate::rans::interleaved`]). The stream is self-describing, so the
//! container neither stores nor cares about the layout; v1-layout
//! containers are byte-identical to every pre-v2 release.

use crate::error::{Error, Result};
use crate::quant::QuantParams;
use crate::rans::FreqTable;
use crate::tensor::Dtype;
use crate::util::{crc32, varint};

/// Container magic bytes.
pub const MAGIC: &[u8; 4] = b"RSC1";
/// Legacy container version: implicit `f32` payload dtype, no tag byte.
pub const VERSION: u8 = 1;
/// Dtype-tagged container version: a [`Dtype::tag`] byte follows `q`.
pub const VERSION_DTYPED: u8 = 2;

/// Plausibility cap on the declared tensor length `T` accepted by the
/// decoders (v1 and v2). Headers are CRC-checked but not authenticated,
/// and a degenerate frequency table can legally decode billions of
/// symbols from a handful of payload bytes — so without this bound a
/// forged header turns into an allocation/CPU bomb on the serving path.
/// 2^28 symbols (≈1 GiB of decoded `u32`s at `ℓ_D ≤ 3T`) is orders of
/// magnitude above any real intermediate-feature tensor.
pub const MAX_DECODE_SYMBOLS: usize = 1 << 28;

/// Parsed container header + payload.
#[derive(Debug, Clone)]
pub struct Container {
    /// Element type of the original tensor (reconstruction target).
    pub dtype: Dtype,
    /// Quantization parameters used by the encoder.
    pub params: QuantParams,
    /// Original flat length `T`.
    pub orig_len: usize,
    /// Reshape rows `N`.
    pub n_rows: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// Entropy-coding alphabet for `D`.
    pub alphabet: usize,
    /// Frequency table (side information).
    pub table: FreqTable,
    /// Interleaved rANS payload.
    pub payload: Vec<u8>,
}

/// Borrowed view of a v1 container, for serialization without owning
/// the table or payload. The engine's pooled encode path holds the
/// frequency table behind an `Arc` shared with in-flight lane jobs;
/// serializing through this view means it never has to deep-copy the
/// table (with its 32 KiB fused decode table) just to emit bytes.
#[derive(Debug, Clone, Copy)]
pub struct ContainerRef<'a> {
    /// Element type of the original tensor (reconstruction target).
    pub dtype: Dtype,
    /// Quantization parameters used by the encoder.
    pub params: QuantParams,
    /// Original flat length `T`.
    pub orig_len: usize,
    /// Reshape rows `N`.
    pub n_rows: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// Entropy-coding alphabet for `D`.
    pub alphabet: usize,
    /// Frequency table (side information).
    pub table: &'a FreqTable,
    /// Interleaved rANS payload.
    pub payload: &'a [u8],
}

impl ContainerRef<'_> {
    /// Serialize to bytes (with trailing CRC). The single definition of
    /// the v1 container wire format; [`Container::to_bytes`] delegates
    /// here.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.payload.len() + 64);
        out.extend_from_slice(MAGIC);
        // f32 keeps the legacy version-1 header (byte-identical wire
        // format); non-f32 tensors emit version 2 with a dtype tag.
        if self.dtype == Dtype::F32 {
            out.push(VERSION);
            out.push(self.params.q);
        } else {
            out.push(VERSION_DTYPED);
            out.push(self.params.q);
            out.push(self.dtype.tag());
        }
        out.extend_from_slice(&self.params.scale.to_le_bytes());
        varint::write_i64(&mut out, self.params.zero as i64);
        varint::write_usize(&mut out, self.orig_len);
        varint::write_usize(&mut out, self.n_rows);
        varint::write_usize(&mut out, self.nnz);
        varint::write_usize(&mut out, self.alphabet);
        self.table.serialize(&mut out);
        varint::write_usize(&mut out, self.payload.len());
        out.extend_from_slice(self.payload);
        let crc = crc32::hash(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }
}

impl Container {
    /// Columns `K = T / N`.
    pub fn n_cols(&self) -> usize {
        if self.n_rows == 0 { 0 } else { self.orig_len / self.n_rows }
    }

    /// Length of the concatenated stream `ℓ_D = 2·nnz + N`.
    pub fn ell_d(&self) -> usize {
        2 * self.nnz + self.n_rows
    }

    /// Borrowed view for serialization.
    pub fn view(&self) -> ContainerRef<'_> {
        ContainerRef {
            dtype: self.dtype,
            params: self.params,
            orig_len: self.orig_len,
            n_rows: self.n_rows,
            nnz: self.nnz,
            alphabet: self.alphabet,
            table: &self.table,
            payload: &self.payload,
        }
    }

    /// Serialize to bytes (with trailing CRC).
    pub fn to_bytes(&self) -> Vec<u8> {
        self.view().to_bytes()
    }

    /// Parse and validate a container.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < MAGIC.len() + 2 + 4 + 4 {
            return Err(Error::corrupt("container shorter than minimum header"));
        }
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
        let stored_crc = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
        let actual_crc = crc32::hash(body);
        if stored_crc != actual_crc {
            return Err(Error::corrupt(format!(
                "crc mismatch: stored {stored_crc:#010x}, computed {actual_crc:#010x}"
            )));
        }
        if &body[0..4] != MAGIC {
            return Err(Error::corrupt("bad magic"));
        }
        if body[4] != VERSION && body[4] != VERSION_DTYPED {
            return Err(Error::corrupt(format!("unsupported version {}", body[4])));
        }
        let q = body[5];
        let mut pos = 6usize;
        let dtype = if body[4] == VERSION_DTYPED {
            if pos >= body.len() {
                return Err(Error::corrupt("dtype-tagged header truncated"));
            }
            let d = Dtype::from_tag(body[pos])?;
            pos += 1;
            d
        } else {
            Dtype::F32
        };
        if pos + 4 > body.len() {
            return Err(Error::corrupt("container header truncated"));
        }
        let scale = f32::from_le_bytes([body[pos], body[pos + 1], body[pos + 2], body[pos + 3]]);
        pos += 4;
        let zero = varint::read_i64(body, &mut pos)?;
        let zero = i32::try_from(zero).map_err(|_| Error::corrupt("zero point overflow"))?;
        let orig_len = varint::read_usize(body, &mut pos)?;
        let n_rows = varint::read_usize(body, &mut pos)?;
        let nnz = varint::read_usize(body, &mut pos)?;
        let alphabet = varint::read_usize(body, &mut pos)?;
        let table = FreqTable::deserialize(body, &mut pos)?;
        let payload_len = varint::read_usize(body, &mut pos)?;
        let end = pos
            .checked_add(payload_len)
            .filter(|&e| e == body.len())
            .ok_or_else(|| Error::corrupt("payload length mismatch"))?;
        let payload = body[pos..end].to_vec();

        // Structural sanity.
        if !(1..=16).contains(&q) {
            return Err(Error::corrupt(format!("bad Q {q}")));
        }
        if !scale.is_finite() || scale <= 0.0 {
            return Err(Error::corrupt("bad scale"));
        }
        if orig_len > MAX_DECODE_SYMBOLS {
            return Err(Error::corrupt(format!(
                "declared tensor length {orig_len} exceeds decode cap {MAX_DECODE_SYMBOLS}"
            )));
        }
        if n_rows == 0 && orig_len != 0 {
            return Err(Error::corrupt("zero rows for nonempty tensor"));
        }
        if n_rows != 0 && orig_len % n_rows != 0 {
            return Err(Error::corrupt("N does not divide T"));
        }
        if nnz > orig_len {
            return Err(Error::corrupt("nnz exceeds tensor size"));
        }
        if table.alphabet() != alphabet {
            return Err(Error::corrupt("alphabet / table size mismatch"));
        }
        let params = QuantParams { q, scale, zero };
        Ok(Container { dtype, params, orig_len, n_rows, nnz, alphabet, table, payload })
    }
}

/// Cheaply read `(dtype, orig_len)` from an RSC1/RSC2-shaped header
/// (both formats share the `magic · version · q · [dtype] · scale ·
/// zero · orig_len` prefix) without CRC validation or payload parsing —
/// `decompress_into` uses this to reject dtype mismatches and short
/// output buffers before paying for a full decode. The single
/// definition for both container formats; corrupt headers that survive
/// this peek are still caught by the real parse.
pub(crate) fn peek_header(
    bytes: &[u8],
    magic: &[u8; 4],
    legacy_version: u8,
    dtyped_version: u8,
) -> Result<(Dtype, usize)> {
    if bytes.len() < 10 || &bytes[0..4] != magic {
        return Err(Error::corrupt(format!(
            "not an {} container",
            String::from_utf8_lossy(magic)
        )));
    }
    let mut pos = 6usize;
    let dtype = match bytes[4] {
        v if v == legacy_version => Dtype::F32,
        v if v == dtyped_version => {
            let d = Dtype::from_tag(bytes[6])?;
            pos += 1;
            d
        }
        v => return Err(Error::corrupt(format!("unsupported version {v}"))),
    };
    pos += 4; // scale
    if pos > bytes.len() {
        return Err(Error::corrupt("container header truncated"));
    }
    varint::read_i64(bytes, &mut pos)?; // zero point
    let orig_len = varint::read_usize(bytes, &mut pos)?;
    Ok((dtype, orig_len))
}

/// [`peek_header`] specialized to the v1 `RSC1` container.
pub(crate) fn peek_dtype_and_len(bytes: &[u8]) -> Result<(Dtype, usize)> {
    peek_header(bytes, MAGIC, VERSION, VERSION_DTYPED)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_container() -> Container {
        let syms: Vec<u32> = vec![1, 2, 3, 0, 1, 2];
        let table = FreqTable::from_symbols(&syms, 8);
        let payload = crate::rans::encode_interleaved(&syms, &table, 2, false).unwrap();
        Container {
            dtype: Dtype::F32,
            params: QuantParams { q: 4, scale: 0.25, zero: 3 },
            orig_len: 64,
            n_rows: 8,
            nnz: 1,
            alphabet: 8,
            table,
            payload,
        }
    }

    #[test]
    fn roundtrip() {
        let c = sample_container();
        let bytes = c.to_bytes();
        let back = Container::from_bytes(&bytes).unwrap();
        assert_eq!(back.dtype, Dtype::F32);
        assert_eq!(back.params, c.params);
        assert_eq!(back.orig_len, c.orig_len);
        assert_eq!(back.n_rows, c.n_rows);
        assert_eq!(back.nnz, c.nnz);
        assert_eq!(back.payload, c.payload);
        assert_eq!(back.n_cols(), 8);
        assert_eq!(back.ell_d(), 2 + 8);
    }

    #[test]
    fn dtyped_roundtrip_and_f32_header_unchanged() {
        let f32_bytes = sample_container().to_bytes();
        assert_eq!(f32_bytes[4], VERSION, "f32 containers keep the legacy version byte");
        for dtype in [Dtype::F16, Dtype::Bf16] {
            let mut c = sample_container();
            c.dtype = dtype;
            let bytes = c.to_bytes();
            assert_eq!(bytes[4], VERSION_DTYPED);
            assert_eq!(bytes[6], dtype.tag());
            // Exactly one extra header byte relative to the f32 form.
            assert_eq!(bytes.len(), f32_bytes.len() + 1);
            let back = Container::from_bytes(&bytes).unwrap();
            assert_eq!(back.dtype, dtype);
            assert_eq!(back.params, c.params);
            assert_eq!(back.payload, c.payload);
            assert_eq!(peek_dtype_and_len(&bytes).unwrap(), (dtype, c.orig_len));
        }
        assert_eq!(
            peek_dtype_and_len(&f32_bytes).unwrap(),
            (Dtype::F32, sample_container().orig_len)
        );
    }

    #[test]
    fn dtyped_bad_tag_and_truncations_rejected() {
        let mut c = sample_container();
        c.dtype = Dtype::Bf16;
        let bytes = c.to_bytes();
        // Unknown dtype tag behind a recomputed CRC is still rejected.
        let (mut body, _) = {
            let (b, _) = bytes.split_at(bytes.len() - 4);
            (b.to_vec(), ())
        };
        body[6] = 7;
        let crc = crc32::hash(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        assert!(Container::from_bytes(&body).is_err());
        // Every truncation of the dtyped header errors cleanly, in both
        // the full parse and the header peek.
        for cut in 0..bytes.len().min(24) {
            assert!(Container::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
            if cut <= 11 {
                assert!(peek_dtype_and_len(&bytes[..cut]).is_err(), "peek cut {cut}");
            }
        }
    }

    #[test]
    fn every_flipped_byte_is_detected() {
        let bytes = sample_container().to_bytes();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x5A;
            assert!(Container::from_bytes(&bad).is_err(), "flip at byte {i} undetected");
        }
    }

    #[test]
    fn truncation_detected() {
        let bytes = sample_container().to_bytes();
        for cut in [0, 1, 4, bytes.len() / 2, bytes.len() - 1] {
            assert!(Container::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn bad_structural_fields_detected() {
        // Hand-build a container with N not dividing T; recompute CRC so
        // only the structural check can catch it.
        let mut c = sample_container();
        c.n_rows = 7;
        let bytes = c.to_bytes();
        assert!(Container::from_bytes(&bytes).is_err());
    }
}
