//! The paper's end-to-end IF compression pipeline (§3.1, Fig. 1c):
//!
//! ```text
//! X ∈ R^{C×H×W} ──reshape──▶ X' ∈ R^{N×K} ──AIQ──▶ X̂ ∈ {0..2^Q−1}^{N×K}
//!   ──modified CSR──▶ (v, c, r) ──concat──▶ D ──rANS──▶ bitstream
//! ```
//!
//! Two entry levels mirror the deployment split:
//! * [`compress_tensor`] / [`decompress_into`] — dtype-tagged zero-copy
//!   tensor views in ([`TensorRef`]: f32, f16, or bf16, converted on
//!   load), caller-owned output buffers out ([`TensorMut`]). The
//!   `&[f32]` forms [`compress`] / [`decompress`] remain as
//!   byte-identical shims.
//! * [`compress_quantized`] / [`decompress_to_symbols`] — integer
//!   symbols in/out. This is the L3 hot path: the AOT'd head artifact
//!   already emits AIQ symbols (the Pallas quantize epilogue), and the
//!   tail artifact consumes symbols (Pallas dequantize prologue), so the
//!   Rust side never touches floats for the IF payload.

pub mod codec;
pub mod container;

pub use crate::tensor::{Dtype, TensorMut, TensorRef};
pub use codec::{
    compress, compress_quantized, compress_tensor, decompress, decompress_into,
    decompress_to_symbols, CompressStats, DecodeInfo, PipelineConfig, ReshapeStrategy,
    StreamLayout,
};
pub use container::Container;
