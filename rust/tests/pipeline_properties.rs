//! Property-based integration tests over the compression stack
//! (no artifacts required).

use rans_sc::pipeline::{self, PipelineConfig, ReshapeStrategy, StreamLayout};
use rans_sc::quant::{quantize, QuantParams};
use rans_sc::rans::{decode, encode, FreqTable};
use rans_sc::sparse::ModCsr;
use rans_sc::testutil;
use rans_sc::util::prng::Rng;

/// Generate a random tensor with random sparsity/scale/shift.
fn gen_tensor(rng: &mut Rng) -> Vec<f32> {
    let len = 1 + rng.below_usize(20_000);
    let sparsity = rng.next_f64();
    let scale = *rng.choose(&[0.01f32, 1.0, 50.0]);
    let shift = *rng.choose(&[-4.0f32, 0.0, 2.0]);
    (0..len)
        .map(|_| {
            if rng.next_f64() < sparsity {
                0.0
            } else {
                rng.normal() as f32 * scale + shift
            }
        })
        .collect()
}

#[test]
fn prop_pipeline_symbol_roundtrip() {
    testutil::check(
        "pipeline symbol roundtrip across Q and strategies",
        40,
        |rng| {
            let data = gen_tensor(rng);
            let q = *rng.choose(&[2u8, 3, 4, 6, 8]);
            let strat = match rng.below(3) {
                0 => ReshapeStrategy::Optimize,
                1 => ReshapeStrategy::Flat,
                _ => ReshapeStrategy::Optimize,
            };
            let states = *rng.choose(&[1usize, 2, 4, 8]);
            (data, q, strat, states)
        },
        |(data, q, strat, states)| {
            let params = match QuantParams::fit(*q, data) {
                Ok(p) => p,
                Err(_) => return false,
            };
            let symbols = quantize(data, &params);
            let cfg = PipelineConfig {
                q: *q,
                lanes: 4,
                parallel: false,
                reshape: strat.clone(),
                layout: if *states == 1 {
                    StreamLayout::V1
                } else {
                    StreamLayout::MultiState(*states)
                },
            };
            let (bytes, _) = match pipeline::compress_quantized(&symbols, params, &cfg) {
                Ok(x) => x,
                Err(_) => return false,
            };
            match pipeline::decompress_to_symbols(&bytes) {
                Ok((back, back_params)) => back == symbols && back_params == params,
                Err(_) => false,
            }
        },
    );
}

#[test]
fn prop_pipeline_rejects_any_single_corruption() {
    testutil::check(
        "any single byte flip is rejected",
        30,
        |rng| {
            let data = gen_tensor(rng);
            let (bytes, _) =
                pipeline::compress(&data, &PipelineConfig::paper(4)).expect("compress");
            let pos = rng.below_usize(bytes.len());
            let bit = 1u8 << rng.below(8);
            (bytes, pos, bit)
        },
        |(bytes, pos, bit)| {
            let mut bad = bytes.clone();
            bad[*pos] ^= bit;
            pipeline::decompress(&bad).is_err()
        },
    );
}

#[test]
fn prop_rans_matches_entropy_budget() {
    // Compressed size ≤ entropy bound within 5% + constant, for any
    // distribution the generator produces.
    testutil::check(
        "rANS size near entropy",
        30,
        |rng| {
            let alphabet = 2 + rng.below_usize(200);
            let skew = 0.5 + rng.next_f64() * 2.0;
            let len = 1000 + rng.below_usize(30_000);
            let symbols: Vec<u32> = (0..len).map(|_| rng.zipf(alphabet, skew) as u32).collect();
            (symbols, alphabet)
        },
        |(symbols, alphabet)| {
            let table = FreqTable::from_symbols(symbols, *alphabet);
            let bytes = match encode(symbols, &table) {
                Ok(b) => b,
                Err(_) => return false,
            };
            let freqs = rans_sc::util::stats::histogram(symbols, *alphabet);
            let bound = rans_sc::util::stats::entropy_bits(&freqs) / 8.0;
            // Normalization quantization costs a little; allow 8% + 64 B.
            (bytes.len() as f64) < bound * 1.08 + 64.0
        },
    );
}

#[test]
fn prop_rans_decode_inverse() {
    testutil::check_shrink(
        "rANS decode ∘ encode = id",
        50,
        |rng| {
            let alphabet = 2 + rng.below_usize(64);
            let len = rng.below_usize(5000);
            (0..len).map(|_| rng.below(alphabet as u64) as u32).collect::<Vec<u32>>()
        },
        |symbols| {
            let alphabet = symbols.iter().copied().max().unwrap_or(0) as usize + 1;
            let table = FreqTable::from_symbols(symbols, alphabet);
            match encode(symbols, &table).and_then(|b| decode(&b, symbols.len(), &table)) {
                Ok(back) => back == *symbols,
                Err(_) => false,
            }
        },
    );
}

#[test]
fn prop_csr_roundtrip_any_matrix() {
    testutil::check(
        "modified CSR roundtrip",
        60,
        |rng| {
            let n = 1 + rng.below_usize(100);
            let k = 1 + rng.below_usize(100);
            let bg = rng.below(16) as u16;
            let m: Vec<u16> = (0..n * k).map(|_| rng.below(16) as u16).collect();
            (m, n, k, bg)
        },
        |(m, n, k, bg)| {
            let csr = match ModCsr::encode(m, *n, *k, *bg) {
                Ok(c) => c,
                Err(_) => return false,
            };
            let d = csr.concat();
            let back = ModCsr::from_concat(&d, csr.nnz(), *n, *k, *bg)
                .and_then(|c| c.decode());
            back.map(|x| x == *m).unwrap_or(false)
        },
    );
}

#[test]
fn prop_quantize_error_bound() {
    testutil::check(
        "AIQ error ≤ one step",
        60,
        |rng| {
            let data = gen_tensor(rng);
            let q = *rng.choose(&[2u8, 3, 4, 6, 8]);
            (data, q)
        },
        |(data, q)| {
            let params = match QuantParams::fit(*q, data) {
                Ok(p) => p,
                Err(_) => return false,
            };
            let rec = rans_sc::quant::dequantize(&quantize(data, &params), &params);
            let tol = params.scale + 1e-5;
            data.iter().zip(&rec).all(|(a, b)| (a - b).abs() <= tol)
                // Exact zeros reconstruct exactly when the range spans 0.
                && data
                    .iter()
                    .zip(&rec)
                    .filter(|(a, _)| **a == 0.0)
                    .all(|(_, b)| *b == 0.0)
        },
    );
}
