//! Integration tests for v2 multi-state streams through the full
//! engine/pipeline stack: round-trips across states × lanes × Q
//! (including tiny inputs where a lane codes fewer symbols than it has
//! states), byte-stability between pooled and serial encoders, and
//! corrupt-header rejection (state count 0 / unsupported / above max,
//! truncated per-state payloads) mirroring the rans-layer garbling
//! tests.

use rans_sc::engine::{Engine, EngineConfig};
use rans_sc::pipeline::{self, PipelineConfig, ReshapeStrategy, StreamLayout};
use rans_sc::quant::{quantize, QuantParams};
use rans_sc::rans::interleaved::parse_stream_spans;
use rans_sc::util::prng::Rng;

fn synth_tensor(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..len)
        .map(|_| if rng.next_f64() < 0.55 { 0.0 } else { rng.normal().abs() as f32 * 1.5 })
        .collect()
}

fn cfg(q: u8, lanes: usize, states: usize, parallel: bool) -> PipelineConfig {
    PipelineConfig {
        q,
        lanes,
        parallel,
        reshape: ReshapeStrategy::Optimize,
        layout: if states == 1 { StreamLayout::V1 } else { StreamLayout::MultiState(states) },
    }
}

#[test]
fn roundtrip_states_by_lanes_by_q() {
    let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() });
    // Decode threading is engine config now; a forced-serial twin keeps
    // both decode paths covered.
    let serial = Engine::new(EngineConfig {
        workers: 4,
        decode_parallel: Some(false),
        ..EngineConfig::default()
    });
    let data = synth_tensor(1, 12_288);
    for q in [2u8, 4, 8] {
        let params = QuantParams::fit(q, &data).unwrap();
        let symbols = quantize(&data, &params);
        for states in [1usize, 2, 4, 8] {
            for lanes in [1usize, 3, 8] {
                let (bytes, _) = engine
                    .compress_quantized(&symbols, params, &cfg(q, lanes, states, true))
                    .unwrap();
                for eng in [&engine, &serial] {
                    let (back, p) = eng.decompress_to_symbols(&bytes).unwrap();
                    assert_eq!(back, symbols, "q={q} states={states} lanes={lanes}");
                    assert_eq!(p, params);
                }
            }
        }
    }
}

#[test]
fn tiny_tensors_where_lanes_outnumber_symbols() {
    // ℓ_D per lane can be 0 or 1 here, so every state-count > symbol
    // edge (idle states, tail rounds) is crossed at the engine level.
    let engine = Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() });
    for len in [1usize, 2, 3, 5, 9] {
        let data = synth_tensor(100 + len as u64, len);
        for states in [2usize, 4, 8] {
            let c = PipelineConfig {
                q: 4,
                lanes: 8,
                parallel: false,
                reshape: ReshapeStrategy::Flat,
                layout: StreamLayout::MultiState(states),
            };
            let (bytes, _) = engine.compress(&data, &c).unwrap();
            let back = engine.decompress(&bytes).unwrap();
            assert_eq!(back.len(), len, "len={len} states={states}");
        }
    }
}

#[test]
fn pooled_and_serial_encoders_byte_identical() {
    let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() });
    let data = synth_tensor(2, 20_000);
    let params = QuantParams::fit(4, &data).unwrap();
    let symbols = quantize(&data, &params);
    for states in [2usize, 4, 8] {
        let (par, _) = engine
            .compress_quantized(&symbols, params, &cfg(4, 8, states, true))
            .unwrap();
        let (ser, _) = engine
            .compress_quantized(&symbols, params, &cfg(4, 8, states, false))
            .unwrap();
        assert_eq!(par, ser, "states={states}");
        // Repeated calls are byte-stable.
        let (again, _) = engine
            .compress_quantized(&symbols, params, &cfg(4, 8, states, true))
            .unwrap();
        assert_eq!(par, again);
    }
}

/// Garble the v2 stream header inside a valid container, recomputing
/// the container CRC so only the stream-level validation can catch it.
#[test]
fn corrupt_v2_stream_headers_rejected() {
    use rans_sc::pipeline::Container;

    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() });
    let data = synth_tensor(3, 4096);
    let params = QuantParams::fit(4, &data).unwrap();
    let symbols = quantize(&data, &params);
    let (bytes, _) = engine
        .compress_quantized(&symbols, params, &cfg(4, 2, 4, false))
        .unwrap();
    let container = Container::from_bytes(&bytes).unwrap();
    // Payload leads with [marker 0][states 4].
    assert_eq!(&container.payload[0..2], &[0u8, 4]);

    let reject_with_states_byte = |b: u8| {
        let mut c = Container::from_bytes(&bytes).unwrap();
        c.payload[1] = b;
        let garbled = c.to_bytes(); // fresh CRC over the garbled payload
        assert!(
            engine.decompress_to_symbols(&garbled).is_err(),
            "states byte {b} must be rejected"
        );
    };
    reject_with_states_byte(0); // state count 0
    reject_with_states_byte(3); // in-range but unsupported
    reject_with_states_byte(5); // above MAX_STATES
    reject_with_states_byte(0x7F); // far above max

    // Truncated per-state payload: shorten the last lane and fix up its
    // declared length so the framing parses but the lane's state-word
    // block is short.
    let parsed = parse_stream_spans(&container.payload).unwrap();
    assert_eq!(parsed.states_per_lane, 4);
    let (_, last) = parsed.lanes.last().unwrap().clone();
    assert!(last.len() >= 16);
    {
        let mut c = Container::from_bytes(&bytes).unwrap();
        // Rebuild the stream with the last lane cut to 10 bytes
        // (< 16 = 4 state words), re-declaring its length so the lane
        // framing still parses and only the multistate decoder can
        // object.
        let mut lens: Vec<usize> = parsed.lanes.iter().map(|(_, r)| r.len()).collect();
        *lens.last_mut().unwrap() = 10;
        let mut payload = Vec::new();
        rans_sc::util::varint::write_usize(&mut payload, 0); // v2 marker
        rans_sc::util::varint::write_usize(&mut payload, 4); // states
        rans_sc::util::varint::write_usize(&mut payload, parsed.lanes.len());
        rans_sc::util::varint::write_usize(&mut payload, parsed.symbol_count);
        for &l in &lens {
            rans_sc::util::varint::write_usize(&mut payload, l);
        }
        for (i, (_, r)) in parsed.lanes.iter().enumerate() {
            let p = &c.payload[r.clone()];
            let keep = if i + 1 == parsed.lanes.len() { &p[..10] } else { p };
            payload.extend_from_slice(keep);
        }
        c.payload = payload;
        let garbled = c.to_bytes();
        assert!(
            engine.decompress_to_symbols(&garbled).is_err(),
            "truncated per-state payload must be rejected"
        );
    }
}

#[test]
fn pipeline_wrappers_accept_v2_streams() {
    // The public pipeline API (shared engine) decodes v2 streams with
    // no knob, and the layout survives the float roundtrip (4- and
    // 8-state payloads take the SIMD decode path where available).
    let data = synth_tensor(4, 6000);
    for states in [4usize, 8] {
        let c = PipelineConfig::paper(4).with_states(states);
        let (bytes, stats) = pipeline::compress(&data, &c).unwrap();
        assert_eq!(stats.total_bytes, bytes.len());
        let back = pipeline::decompress(&bytes).unwrap();
        assert_eq!(back.len(), data.len(), "states={states}");
    }
}
