//! Dtype-generic codec API integration tests.
//!
//! Three walls:
//! * **Converter wall** — every one of the 65,536 f16 bit patterns (and
//!   the full bf16 sweep, NaN/inf/subnormals included) round-trips
//!   through f32 bit-identically, and all four conversion directions
//!   are pinned by CRC against the independent Python reference in
//!   `gen_golden.py` (committed as `golden/half_conv_crcs.hex`; the
//!   Python side additionally cross-checks `struct`'s native binary16
//!   codec).
//! * **API wall** — `compress_tensor`/`decompress_into` round-trips per
//!   dtype and storage form, plus the error paths (dtype mismatch,
//!   short buffer).
//! * **Coordinator wall** — a bf16 tensor compresses and decompresses
//!   end-to-end through the coordinator's in-proc transport: quantize
//!   fuses the bf16→f32 conversion into its loads
//!   (`quant::fit_and_quantize_tensor`), so no intermediate `f32` `Vec`
//!   is ever allocated on the quantize path, and the cloud side decodes
//!   zero-copy into a reused bf16 arena.

use rans_sc::coordinator::{Frame, FrameKind, InProcTransport, Transport};
use rans_sc::engine::{Engine, EngineConfig};
use rans_sc::pipeline::{self, PipelineConfig};
use rans_sc::tensor::{half, Dtype, TensorMut, TensorRef};
use rans_sc::util::crc32;
use rans_sc::util::prng::Rng;

// ----------------------------------------------------- converter wall

/// The deterministic f32 bit-pattern sweep the narrowing CRCs cover;
/// mirrors `narrowing_sweep_inputs()` in gen_golden.py exactly.
fn narrowing_sweep() -> impl Iterator<Item = u32> {
    let structured = (0..256u32).flat_map(|e| {
        [0u32, 1, 0x1000, 0x0FFF, 0x2000, 0x003F_FFFF, 0x0040_0000, 0x007F_FFFF]
            .into_iter()
            .flat_map(move |m| [0u32, 1].into_iter().map(move |s| (s << 31) | (e << 23) | m))
    });
    let mut lcg: u64 = 0x0D_D015_EA5E;
    let random = (0..1usize << 18).map(move |_| {
        lcg = lcg
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        (lcg >> 32) as u32
    });
    structured.chain(random)
}

/// The four reference CRCs from gen_golden.py, in emission order:
/// f16→f32 table, bf16→f32 table, f32→f16 sweep, f32→bf16 sweep.
fn golden_crcs() -> [u32; 4] {
    let hex = include_str!("golden/half_conv_crcs.hex").trim();
    assert_eq!(hex.len(), 32, "half_conv_crcs.hex must hold four LE u32 CRCs");
    let bytes: Vec<u8> = (0..16)
        .map(|i| u8::from_str_radix(&hex[2 * i..2 * i + 2], 16).unwrap())
        .collect();
    [0, 1, 2, 3].map(|i| u32::from_le_bytes(bytes[4 * i..4 * i + 4].try_into().unwrap()))
}

#[test]
fn exhaustive_f16_and_bf16_roundtrip_through_f32() {
    for bits in 0..=u16::MAX {
        assert_eq!(
            half::f32_bits_to_f16_bits(half::f16_bits_to_f32_bits(bits)),
            bits,
            "f16 {bits:#06x}"
        );
        assert_eq!(
            half::f32_bits_to_bf16_bits(half::bf16_bits_to_f32_bits(bits)),
            bits,
            "bf16 {bits:#06x}"
        );
    }
}

#[test]
fn widening_tables_match_python_reference_crcs() {
    let [want_f16, want_bf16, _, _] = golden_crcs();
    let mut table = Vec::with_capacity(4 << 16);
    for h in 0..=u16::MAX {
        table.extend_from_slice(&half::f16_bits_to_f32_bits(h).to_le_bytes());
    }
    assert_eq!(crc32::hash(&table), want_f16, "f16→f32 table drifted from gen_golden.py");
    let mut table = Vec::with_capacity(4 << 16);
    for b in 0..=u16::MAX {
        table.extend_from_slice(&half::bf16_bits_to_f32_bits(b).to_le_bytes());
    }
    assert_eq!(crc32::hash(&table), want_bf16, "bf16→f32 table drifted from gen_golden.py");
}

#[test]
fn narrowing_sweeps_match_python_reference_crcs() {
    let [_, _, want_f16, want_bf16] = golden_crcs();
    let mut t16 = Vec::new();
    let mut tbf = Vec::new();
    for bits in narrowing_sweep() {
        t16.extend_from_slice(&half::f32_bits_to_f16_bits(bits).to_le_bytes());
        tbf.extend_from_slice(&half::f32_bits_to_bf16_bits(bits).to_le_bytes());
    }
    assert_eq!(crc32::hash(&t16), want_f16, "f32→f16 sweep drifted from gen_golden.py");
    assert_eq!(crc32::hash(&tbf), want_bf16, "f32→bf16 sweep drifted from gen_golden.py");
}

// ----------------------------------------------------------- API wall

fn synth_tensor(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..len)
        .map(|_| if rng.next_f64() < 0.55 { 0.0 } else { rng.normal().abs() as f32 * 1.5 })
        .collect()
}

#[test]
fn every_dtype_and_storage_roundtrips_through_the_public_api() {
    let data = synth_tensor(1, 6000);
    let cfg = PipelineConfig::paper(6);
    let f16: Vec<u16> = data.iter().map(|&x| half::f32_to_f16(x)).collect();
    let bf16: Vec<u16> = data.iter().map(|&x| half::f32_to_bf16(x)).collect();
    for dtype in [Dtype::F32, Dtype::F16, Dtype::Bf16] {
        let tensor = match dtype {
            Dtype::F32 => TensorRef::from_f32(&data),
            Dtype::F16 => TensorRef::from_f16_bits(&f16),
            Dtype::Bf16 => TensorRef::from_bf16_bits(&bf16),
        };
        let wire = tensor.to_le_bytes();
        let (a, _) = pipeline::compress_tensor(tensor, &cfg).unwrap();
        // The raw-bytes storage form of the same tensor compresses to
        // the same container.
        let (b, _) = pipeline::compress_tensor(
            TensorRef::from_le_bytes(dtype, &wire).unwrap(),
            &cfg,
        )
        .unwrap();
        assert_eq!(a, b, "{dtype}: typed and raw-byte views must agree");
        // Zero-copy decode into a raw little-endian byte buffer matches
        // the typed buffer element-for-element.
        let mut raw_out = vec![0u8; wire.len()];
        let info = pipeline::decompress_into(
            &a,
            TensorMut::from_le_bytes(dtype, &mut raw_out).unwrap(),
        )
        .unwrap();
        assert_eq!(info.dtype, dtype);
        assert_eq!(info.elements, data.len());
        let restored = TensorRef::from_le_bytes(dtype, &raw_out).unwrap().to_f32_vec();
        let widened = TensorRef::from_le_bytes(dtype, &wire).unwrap().to_f32_vec();
        for (i, (orig, got)) in widened.iter().zip(&restored).enumerate() {
            let tol = info.params.scale * 1.01 + orig.abs() * 0.01 + 1e-5;
            assert!((orig - got).abs() <= tol, "{dtype} i={i}: {orig} vs {got}");
            if *orig == 0.0 {
                assert_eq!(*got, 0.0, "{dtype} i={i}: sparsity must survive");
            }
        }
    }
}

#[test]
fn decompress_into_error_paths() {
    let data = synth_tensor(2, 2048);
    let f16: Vec<u16> = data.iter().map(|&x| half::f32_to_f16(x)).collect();
    let (bytes, _) =
        pipeline::compress_tensor(TensorRef::from_f16_bits(&f16), &PipelineConfig::paper(4))
            .unwrap();
    // Dtype mismatch against the header tag.
    let mut bf16_out = vec![0u16; f16.len()];
    assert!(
        pipeline::decompress_into(&bytes, TensorMut::from_bf16_bits(&mut bf16_out)).is_err()
    );
    let mut f32_out = vec![0.0f32; f16.len()];
    assert!(pipeline::decompress_into(&bytes, TensorMut::from_f32(&mut f32_out)).is_err());
    // Short output buffer.
    let mut short = vec![0u16; f16.len() - 1];
    assert!(
        pipeline::decompress_into(&bytes, TensorMut::from_f16_bits(&mut short)).is_err()
    );
    // Empty buffer, nonempty container.
    let mut empty: Vec<u16> = Vec::new();
    assert!(
        pipeline::decompress_into(&bytes, TensorMut::from_f16_bits(&mut empty)).is_err()
    );
    // The happy path still works after all those rejections.
    let mut ok = vec![0u16; f16.len()];
    pipeline::decompress_into(&bytes, TensorMut::from_f16_bits(&mut ok)).unwrap();
}

// --------------------------------------------------- coordinator wall

/// A bf16 tensor end-to-end through the coordinator's in-proc
/// transport: edge-side `compress_tensor` (quantize fuses the bf16→f32
/// conversion into its loads — no intermediate `f32` `Vec` exists on
/// the quantize path, by construction of
/// `quant::fit_and_quantize_tensor`), the `InferLm` frame over the
/// wire, and a cloud-side zero-copy `decompress_into` a reused bf16
/// arena.
#[test]
fn bf16_end_to_end_through_inproc_transport() {
    let hidden = synth_tensor(3, 4096);
    let bf16: Vec<u16> = hidden.iter().map(|&x| half::f32_to_bf16(x)).collect();
    let n = bf16.len();

    let (mut edge_end, mut cloud_end) = InProcTransport::pair();
    let server = std::thread::spawn(move || {
        let engine = Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() });
        // The decode arena is allocated once and reused across requests
        // (sized generously; decompress_into writes a prefix).
        let mut arena = vec![0u16; 1 << 16];
        loop {
            let frame = match cloud_end.recv() {
                Ok(f) => f,
                Err(_) => return,
            };
            match frame.kind {
                FrameKind::InferLm { payload, .. } => {
                    let info = engine
                        .decompress_into(&payload, TensorMut::from_bf16_bits(&mut arena))
                        .unwrap();
                    assert_eq!(info.dtype, Dtype::Bf16, "header dtype tag must survive");
                    // Stand-in tail compute: widen the decoded features.
                    let logits =
                        TensorRef::from_bf16_bits(&arena[..info.elements]).to_f32_vec();
                    cloud_end
                        .send(&Frame::new(
                            frame.request_id,
                            FrameKind::Logits {
                                data: logits,
                                decode_ms: 0.0,
                                compute_ms: 0.0,
                            },
                        ))
                        .unwrap();
                }
                FrameKind::Shutdown => {
                    let _ = cloud_end.send(&Frame::new(frame.request_id, FrameKind::Pong));
                    return;
                }
                other => panic!("unexpected frame {other:?}"),
            }
        }
    });

    // Edge side: compress the borrowed bf16 tensor and ship it. Two
    // requests exercise arena reuse on the cloud side.
    for req in 1..=2u64 {
        let (container, stats) = pipeline::compress_tensor(
            TensorRef::from_bf16_bits(&bf16),
            &PipelineConfig::paper(6),
        )
        .unwrap();
        assert!(container.len() < 2 * n, "must beat raw bf16 bytes");
        assert_eq!(stats.total_bytes, container.len());
        edge_end
            .send(&Frame::new(
                req,
                FrameKind::InferLm { model: "llama_mini_s".into(), payload: container },
            ))
            .unwrap();
        let reply = edge_end.recv().unwrap();
        assert_eq!(reply.request_id, req);
        let FrameKind::Logits { data, .. } = reply.kind else {
            panic!("expected logits, got {:?}", reply.kind)
        };
        assert_eq!(data.len(), n);
        // Reconstruction error bounded by the quantization step on the
        // widened values.
        let widened: Vec<f32> = bf16.iter().map(|&b| half::bf16_to_f32(b)).collect();
        let params = rans_sc::quant::fit_and_quantize_tensor(
            6,
            &TensorRef::from_bf16_bits(&bf16),
        )
        .unwrap()
        .0;
        for (i, (orig, got)) in widened.iter().zip(&data).enumerate() {
            let tol = params.scale * 1.01 + orig.abs() * 0.01 + 1e-5;
            assert!((orig - got).abs() <= tol, "i={i}: {orig} vs {got}");
            if *orig == 0.0 {
                assert_eq!(*got, 0.0, "i={i}: sparsity must survive the link");
            }
        }
    }
    edge_end.send(&Frame::new(99, FrameKind::Shutdown)).unwrap();
    let _ = edge_end.recv();
    server.join().unwrap();
}
