//! Delta-sync + store-hardening wall: the four PR-9 bugfix
//! regressions, the wire-level delta-sync walls, and an
//! integration-level CDC boundary-shift property.
//!
//! Contracts under test:
//!
//! * A dedup hit in `put_chunk` verifies the existing on-disk object
//!   and atomically repairs a poisoned one (counted by
//!   `repair_count`) — a crashed earlier write can never shadow good
//!   bytes forever.
//! * `verify_artifact` streams chunk-by-chunk: the sink surface of
//!   `stream_artifact` never sees more than one chunk at a time.
//! * A non-canonical manifest filename (`007.json` next to `7.json`'s
//!   slot) is a loud typed error, not a silently shadowed version.
//! * `registry fetch` produces bytes on disk (`Deployment::write_to`),
//!   not just a printed size.
//! * Over the wire (tags 17–20): a tampered chunk is a non-retryable
//!   `Corrupt` and never lands in the local store; a sync killed
//!   mid-stream over a lossy `FaultyTransport` resumes from its
//!   sidecar without re-downloading a single completed chunk.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use rans_sc::coordinator::{
    FaultSpec, FaultyTransport, Frame, FrameKind, InProcTransport, RegistryProvider, Session,
    SessionConfig, Transport, WireSource,
};
use rans_sc::error::Error;
use rans_sc::runtime::registry::{
    cdc, sync_deployment, CdcParams, ChunkStore, DeployParams, HmacSha256Signer,
    RegistryManifest, SyncOptions,
};

/// Self-cleaning scratch directory (no tempfile crate in the offline
/// container).
struct Scratch(PathBuf);

impl Scratch {
    fn new(name: &str) -> Self {
        let dir = std::env::temp_dir()
            .join(format!("rans_sc_delta_wall_{}_{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        Scratch(dir)
    }

    fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

fn signer() -> HmacSha256Signer {
    HmacSha256Signer::new(b"delta-wall-key".to_vec(), "test-key")
}

/// Deterministic pseudo-random artifact bytes.
fn artifact_bytes(seed: u64, n: usize) -> Vec<u8> {
    let mut rng = rans_sc::util::prng::Rng::new(seed);
    (0..n).map(|_| rng.next_u64() as u8).collect()
}

/// Publish one multi-chunk deployment (64-byte chunks so artifacts
/// span several objects) and return its manifest.
fn publish(store: &ChunkStore, version: u64, head: &[u8], tail: &[u8]) -> RegistryManifest {
    let manifest = RegistryManifest {
        model: "resnet_mini_synth_a".into(),
        model_version: version,
        deploy: DeployParams::paper(4),
        head: store.put_artifact(head, 64).unwrap(),
        tail: store.put_artifact(tail, 64).unwrap(),
    };
    store.publish(&manifest, &signer()).unwrap();
    manifest
}

// ---------------------------------------------------------------- //
// Bugfix regressions                                                //
// ---------------------------------------------------------------- //

/// Satellite 1: a crashed or bit-rotted object under a chunk address
/// must not survive a dedup hit. `put_chunk` of the same payload
/// verifies the existing frame, rewrites it atomically, and counts
/// the repair — and the artifact verifies end-to-end afterwards.
#[test]
fn poisoned_object_is_repaired_on_dedup_hit() {
    let s = Scratch::new("repair");
    let store = ChunkStore::open(s.path());
    let head = artifact_bytes(0xA1, 300);
    let desc = store.put_artifact(&head, 64).unwrap();
    assert_eq!(store.repair_count(), 0);

    // Poison one object on disk (payload byte inside the frame).
    let victim = store.chunk_path(&desc.chunks[1].sha256);
    let mut raw = fs::read(&victim).unwrap();
    let mid = raw.len() / 2;
    raw[mid] ^= 0xFF;
    fs::write(&victim, &raw).unwrap();
    assert!(store.verify_artifact(&desc).is_err(), "poison must be visible");

    // Re-publishing the same bytes hits the dedup path for every
    // chunk; the poisoned one is detected and rewritten in place.
    let desc2 = store.put_artifact(&head, 64).unwrap();
    assert_eq!(desc2.sha256, desc.sha256);
    assert_eq!(store.repair_count(), 1, "exactly one object needed repair");
    assert_eq!(store.verify_artifact(&desc).unwrap(), head.len() as u64);
}

/// Satellite 2: verification is streaming. The sink never sees a
/// slice longer than one chunk, and the slices reassemble the exact
/// artifact — O(chunk) peak memory instead of O(artifact).
#[test]
fn verify_streams_one_chunk_at_a_time() {
    let s = Scratch::new("stream");
    let store = ChunkStore::open(s.path());
    let chunk_len = 4096usize;
    let data = artifact_bytes(0xB2, chunk_len * 8 + 77);
    let desc = store.put_artifact(&data, chunk_len).unwrap();

    let mut max_slice = 0usize;
    let mut reassembled = Vec::new();
    let total = store
        .stream_artifact(&desc, |slice| {
            max_slice = max_slice.max(slice.len());
            reassembled.extend_from_slice(slice);
            Ok(())
        })
        .unwrap();
    assert_eq!(total, data.len() as u64);
    assert_eq!(reassembled, data);
    assert!(
        max_slice <= chunk_len,
        "sink saw a {max_slice}-byte slice; streaming verify must be O(chunk)"
    );
    // verify_artifact is the same walk with a null sink.
    assert_eq!(store.verify_artifact(&desc).unwrap(), data.len() as u64);
}

/// Satellite 3: `"007".parse::<u64>()` is `Ok(7)`, so a stray
/// `007.json` used to shadow (or race) the canonical `7.json` slot in
/// latest-version resolution. Non-canonical stems are now a loud
/// typed error naming the file.
#[test]
fn non_canonical_manifest_filename_is_rejected() {
    let s = Scratch::new("canon");
    let store = ChunkStore::open(s.path());
    publish(&store, 7, &artifact_bytes(0xC3, 200), &artifact_bytes(0xC4, 100));

    let dir = s.path().join("manifests").join("resnet_mini_synth_a");
    fs::copy(dir.join("7.json"), dir.join("007.json")).unwrap();

    let err = store.latest_version("resnet_mini_synth_a").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("non-canonical"), "{msg}");
    assert!(msg.contains("007"), "error must name the stray file: {msg}");
    // Latest-version fetch goes through the same resolution.
    assert!(store.fetch("resnet_mini_synth_a", None, &signer()).is_err());
    // An explicit version bypasses directory scanning and still works.
    store.fetch("resnet_mini_synth_a", Some(7), &signer()).unwrap();
}

/// Satellite 4: a fetch must produce deployable bytes on disk, not
/// just printed sizes. `Deployment::write_to` lands both halves
/// atomically and byte-identically.
#[test]
fn fetched_deployment_writes_both_halves_to_disk() {
    let s = Scratch::new("writeto");
    let store = ChunkStore::open(s.path().join("reg"));
    let head = artifact_bytes(0xD5, 300);
    let tail = artifact_bytes(0xD6, 150);
    publish(&store, 1, &head, &tail);

    let dep = store.fetch("resnet_mini_synth_a", None, &signer()).unwrap();
    let out = s.path().join("out");
    fs::create_dir_all(&out).unwrap();
    let head_out = out.join("head.bin");
    let tail_out = out.join("tail.bin");
    dep.write_to(&head_out, &tail_out).unwrap();
    assert_eq!(fs::read(&head_out).unwrap(), head);
    assert_eq!(fs::read(&tail_out).unwrap(), tail);
}

// ---------------------------------------------------------------- //
// Wire-level delta sync                                             //
// ---------------------------------------------------------------- //

fn fast_session_cfg() -> SessionConfig {
    SessionConfig {
        deadline_ms: 10_000,
        try_timeout_ms: 500,
        max_retries: 8,
        base_backoff_ms: 1,
        max_backoff_ms: 4,
        heartbeat_ms: 0,
        seed: 0xF00D,
    }
}

/// Serve registry frames (tags 17/19) from `root` on its own thread,
/// optionally flipping a bit in every chunk payload. Counts chunks
/// served. Exits when the peer hangs up; injected link faults from a
/// `FaultyTransport` are skipped like a real accept loop would.
fn serve_registry<T: Transport + 'static>(
    mut transport: T,
    root: PathBuf,
    tamper_chunks: bool,
    served: Arc<AtomicU64>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let provider = RegistryProvider::new(ChunkStore::open(&root));
        loop {
            let frame = match transport.recv() {
                Ok(f) => f,
                Err(e) if e.to_string().contains("injected link fault") => continue,
                Err(_) => return, // peer closed
            };
            let mut reply = provider.try_serve(&frame.kind).unwrap_or_else(|| {
                FrameKind::ServerError { message: "not a registry frame".into() }
            });
            if let FrameKind::ChunkReply { payload } = &mut reply {
                served.fetch_add(1, Ordering::Relaxed);
                if tamper_chunks && !payload.is_empty() {
                    payload[0] ^= 0x01;
                }
            }
            if transport.send(&Frame::new(frame.request_id, reply)).is_err() {
                return;
            }
        }
    })
}

/// End to end over a clean in-proc link: an edge with nothing syncs
/// v1, then delta-syncs v2 moving only the changed chunk, and can
/// serve both versions offline afterwards.
#[test]
fn wire_sync_end_to_end_moves_only_missing_chunks() {
    let s = Scratch::new("wire");
    let publisher = ChunkStore::open(s.path().join("pub"));
    let head1 = artifact_bytes(0xE0, 64 * 16);
    let tail1 = artifact_bytes(0xE1, 64 * 4);
    publish(&publisher, 1, &head1, &tail1);
    let mut head2 = head1.clone();
    head2[0] ^= 0xFF; // one chunk's worth of fine-tune drift
    publish(&publisher, 2, &head2, &tail1);

    let (client, server) = InProcTransport::pair();
    let served = Arc::new(AtomicU64::new(0));
    let handle = serve_registry(server, s.path().join("pub"), false, served.clone());

    let edge = ChunkStore::open(s.path().join("edge"));
    let mut source = WireSource::new(Session::new(client, fast_session_cfg()));
    let (m1, r1) =
        sync_deployment(&edge, &mut source, &signer(), "resnet_mini_synth_a", 1,
            &SyncOptions::default())
        .unwrap();
    assert_eq!(m1.model_version, 1);
    assert_eq!(r1.bytes_fetched, (head1.len() + tail1.len()) as u64);
    // Delta to latest (version 0): one 64-byte chunk crosses the wire.
    let (m2, r2) =
        sync_deployment(&edge, &mut source, &signer(), "resnet_mini_synth_a", 0,
            &SyncOptions::default())
        .unwrap();
    assert_eq!(m2.model_version, 2);
    assert_eq!(r2.chunks_fetched, 1);
    assert_eq!(r2.bytes_fetched, 64);
    assert_eq!(served.load(Ordering::Relaxed), 20 + 1);

    drop(source); // hang up so the responder exits
    handle.join().unwrap();

    // Both versions now serve offline, every byte verified.
    let dep1 = edge.fetch("resnet_mini_synth_a", Some(1), &signer()).unwrap();
    assert_eq!(dep1.head, head1);
    let dep2 = edge.fetch("resnet_mini_synth_a", Some(2), &signer()).unwrap();
    assert_eq!(dep2.head, head2);
    assert_eq!(dep2.tail, tail1);
}

/// A server (or link) flipping chunk bytes is a non-retryable
/// `Corrupt` error, and the tainted payload never lands in the edge
/// store.
#[test]
fn tampered_wire_chunk_is_typed_fatal_and_never_stored() {
    let s = Scratch::new("wiretamper");
    let publisher = ChunkStore::open(s.path().join("pub"));
    let m = publish(&publisher, 1, &artifact_bytes(0xF0, 256), &artifact_bytes(0xF1, 64));

    let (client, server) = InProcTransport::pair();
    let served = Arc::new(AtomicU64::new(0));
    let handle = serve_registry(server, s.path().join("pub"), true, served);

    let edge = ChunkStore::open(s.path().join("edge"));
    let mut source = WireSource::new(Session::new(client, fast_session_cfg()));
    let err =
        sync_deployment(&edge, &mut source, &signer(), "resnet_mini_synth_a", 1,
            &SyncOptions::default())
        .unwrap_err();
    assert!(matches!(err, Error::Corrupt(_)), "{err}");
    assert!(!err.is_retryable(), "tampering must not be retried into acceptance: {err}");
    for chunk in m.all_chunks() {
        assert!(!edge.chunk_path(&chunk.sha256).exists(), "tainted chunk stored");
    }
    // The manifest was never adopted either.
    assert!(edge.load_manifest("resnet_mini_synth_a", Some(1), &signer()).is_err());

    drop(source);
    handle.join().unwrap();
}

/// The resume wall, over a lossy link: kill the sync after 5 chunk
/// downloads (on top of a FaultyTransport dropping 10% of frames —
/// the session's retries absorb those), then resume with a fresh
/// session. The completed chunks are reused from the sidecar-backed
/// local store; not one is re-downloaded.
#[test]
fn dropped_wire_sync_resumes_from_verified_partial_progress() {
    let s = Scratch::new("wireresume");
    let publisher = ChunkStore::open(s.path().join("pub"));
    let head = artifact_bytes(0xAA, 64 * 12);
    let tail = artifact_bytes(0xAB, 64 * 3);
    publish(&publisher, 1, &head, &tail);

    let spec = FaultSpec::drops(0.10);
    let (client, server) = FaultyTransport::pair(0xC0FFEE, spec, spec);
    let served = Arc::new(AtomicU64::new(0));
    let handle = serve_registry(server, s.path().join("pub"), false, served.clone());

    let edge = ChunkStore::open(s.path().join("edge"));
    let mut source = WireSource::new(Session::new(client, fast_session_cfg()));
    let err = sync_deployment(
        &edge,
        &mut source,
        &signer(),
        "resnet_mini_synth_a",
        1,
        &SyncOptions { abort_after: Some(5) },
    )
    .unwrap_err();
    assert!(err.is_retryable(), "a mid-stream drop must look like a link fault: {err}");
    // Half-synced: manifest not adopted yet.
    assert!(edge.load_manifest("resnet_mini_synth_a", Some(1), &signer()).is_err());

    let (m, r) =
        sync_deployment(&edge, &mut source, &signer(), "resnet_mini_synth_a", 1,
            &SyncOptions::default())
        .unwrap();
    assert_eq!(m.model_version, 1);
    assert_eq!(r.chunks_reused, 5, "completed chunks must be reused, not re-downloaded");
    assert_eq!(r.chunks_resumed, 5, "reuse must come from the interrupted run's sidecar");
    assert_eq!(r.chunks_fetched, 10);

    drop(source);
    handle.join().unwrap();
    edge.fetch("resnet_mini_synth_a", Some(1), &signer()).unwrap();
}

// ---------------------------------------------------------------- //
// CDC boundary-shift property                                       //
// ---------------------------------------------------------------- //

/// Content-defined chunking must localize damage: inserting a few
/// bytes anywhere in an artifact may only change chunk addresses near
/// the insertion point — the bulk of the chunk set (and therefore the
/// delta plan) is preserved. Fixed-size chunking fails this by
/// construction for any insertion not at the tail.
#[test]
fn cdc_insertions_shift_boundaries_only_locally() {
    let params = CdcParams::with_avg(1 << 12).unwrap();
    let base = artifact_bytes(0x5EED, 192 * 1024);
    let base_addrs: std::collections::HashSet<String> = chunk_addrs(&base, &params);

    let mut rng = rans_sc::util::prng::Rng::new(0x175E);
    for trial in 0..8u64 {
        let offset = (rng.next_u64() as usize) % base.len();
        let insert_len = 1 + (rng.next_u64() as usize) % 32;
        let inserted: Vec<u8> = (0..insert_len).map(|_| rng.next_u64() as u8).collect();
        let mut edited = Vec::with_capacity(base.len() + insert_len);
        edited.extend_from_slice(&base[..offset]);
        edited.extend_from_slice(&inserted);
        edited.extend_from_slice(&base[offset..]);

        let edited_addrs = chunk_addrs(&edited, &params);
        let shared = edited_addrs.iter().filter(|a| base_addrs.contains(*a)).count();
        assert!(
            shared * 4 >= edited_addrs.len() * 3,
            "trial {trial}: insertion of {insert_len} B at {offset} kept only \
             {shared}/{} chunk addresses",
            edited_addrs.len()
        );
    }
}

/// Chunk the bytes with `cdc::split` and address each chunk.
fn chunk_addrs(bytes: &[u8], params: &CdcParams) -> std::collections::HashSet<String> {
    let mut addrs = std::collections::HashSet::new();
    let mut start = 0usize;
    for len in cdc::split(bytes, params).unwrap() {
        addrs.insert(rans_sc::util::sha256::to_hex(&rans_sc::util::sha256::hash(
            &bytes[start..start + len],
        )));
        start += len;
    }
    addrs
}
