//! Registry tamper wall: publish → tamper → fetch, exhaustively.
//!
//! The contract under test is the ISSUE's acceptance bar for the signed
//! content-addressed registry: **any** flipped bit, truncation, wrong
//! key, or stale-version replay must surface as a loud typed error
//! (`Corrupt` / `Artifact` / `InvalidArg` / `VersionSkew`, all
//! non-retryable) — never a silent success, panic, or hang. The
//! hot-swap half asserts the other side of the contract: a swap under
//! concurrent readers loses zero requests and a failed smoke check
//! rolls back by never flipping.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use rans_sc::error::Error;
use rans_sc::runtime::registry::{
    ChunkStore, DeployParams, HmacSha256Signer, ModelSlot, RegistryManifest,
};

/// Self-cleaning scratch directory (no tempfile crate in the offline
/// container).
struct Scratch(PathBuf);

impl Scratch {
    fn new(name: &str) -> Self {
        let dir = std::env::temp_dir()
            .join(format!("rans_sc_registry_{}_{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        Scratch(dir)
    }

    fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

fn signer() -> HmacSha256Signer {
    HmacSha256Signer::new(b"tamper-wall-key".to_vec(), "test-key")
}

/// Deterministic pseudo-random artifact bytes.
fn artifact_bytes(seed: u64, n: usize) -> Vec<u8> {
    let mut rng = rans_sc::util::prng::Rng::new(seed);
    (0..n).map(|_| rng.next_u64() as u8).collect()
}

/// Publish one multi-chunk deployment and return (store, manifest).
/// Small chunks so the head spans several objects and per-chunk
/// verification actually gets exercised.
fn publish_v1(root: &Path) -> (ChunkStore, RegistryManifest) {
    let store = ChunkStore::open(root);
    let head = artifact_bytes(0xAB, 300);
    let tail = artifact_bytes(0xCD, 150);
    let manifest = RegistryManifest {
        model: "resnet_mini_synth_a".into(),
        model_version: 1,
        deploy: DeployParams::paper(4),
        head: store.put_artifact(&head, 64).unwrap(),
        tail: store.put_artifact(&tail, 64).unwrap(),
    };
    store.publish(&manifest, &signer()).unwrap();
    (store, manifest)
}

/// Every chunk object file under the registry root.
fn chunk_files(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let objects = root.join("objects");
    for shard in fs::read_dir(&objects).unwrap() {
        for f in fs::read_dir(shard.unwrap().path()).unwrap() {
            out.push(f.unwrap().path());
        }
    }
    out.sort();
    assert!(out.len() >= 5, "expected a multi-chunk publish, got {} objects", out.len());
    out
}

fn assert_fatal(err: &Error, what: &str) {
    assert!(!err.is_retryable(), "{what}: {err} must be fatal (resend reproduces it)");
    assert!(
        matches!(
            err,
            Error::Corrupt(_) | Error::Artifact(_) | Error::InvalidArg(_) | Error::VersionSkew { .. }
        ),
        "{what}: {err} must be a typed registry error"
    );
}

#[test]
fn clean_publish_fetch_roundtrip() {
    let scratch = Scratch::new("clean");
    let (store, manifest) = publish_v1(scratch.path());
    let dep = store.fetch("resnet_mini_synth_a", None, &signer()).unwrap();
    assert_eq!(dep.manifest.model_version, 1);
    assert_eq!(dep.head, artifact_bytes(0xAB, 300));
    assert_eq!(dep.tail, artifact_bytes(0xCD, 150));
    assert_eq!(dep.manifest.deploy, manifest.deploy);
    // Explicit-version and verify-only paths agree.
    store.fetch("resnet_mini_synth_a", Some(1), &signer()).unwrap();
    assert_eq!(store.verify_artifact(&manifest.head).unwrap(), 300);
}

/// The headline property: flip EVERY byte of EVERY chunk object, one at
/// a time, and fetch. Magic, length framing, payload, and CRC trailer
/// are all covered — every single flip must be a typed fatal error.
#[test]
fn every_flipped_chunk_byte_is_a_loud_typed_error() {
    let scratch = Scratch::new("bitflip");
    let (store, _) = publish_v1(scratch.path());
    for path in chunk_files(scratch.path()) {
        let original = fs::read(&path).unwrap();
        for offset in 0..original.len() {
            let mut bad = original.clone();
            bad[offset] ^= 0x01;
            fs::write(&path, &bad).unwrap();
            let err = store
                .fetch("resnet_mini_synth_a", None, &signer())
                .expect_err(&format!("flip at {}:{offset} must not verify", path.display()));
            assert_fatal(&err, &format!("{}:{offset}", path.display()));
        }
        fs::write(&path, &original).unwrap();
    }
    // The wall left the store intact: a clean fetch still passes.
    store.fetch("resnet_mini_synth_a", None, &signer()).unwrap();
}

#[test]
fn truncated_chunk_is_rejected_before_later_chunks_are_read() {
    let scratch = Scratch::new("truncate");
    let (store, _) = publish_v1(scratch.path());
    for path in chunk_files(scratch.path()) {
        let original = fs::read(&path).unwrap();
        for keep in [0, 7, 8, original.len() / 2, original.len() - 1] {
            fs::write(&path, &original[..keep]).unwrap();
            let err = store.fetch("resnet_mini_synth_a", None, &signer()).unwrap_err();
            assert_fatal(&err, &format!("{} truncated to {keep}", path.display()));
        }
        fs::write(&path, &original).unwrap();
    }
}

#[test]
fn absent_chunk_is_a_typed_artifact_error() {
    let scratch = Scratch::new("absent");
    let (store, _) = publish_v1(scratch.path());
    let victim = &chunk_files(scratch.path())[0];
    fs::remove_file(victim).unwrap();
    let err = store.fetch("resnet_mini_synth_a", None, &signer()).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
    assert!(err.to_string().contains("absent"), "{err}");
}

#[test]
fn manifest_tampering_breaks_the_signature() {
    let scratch = Scratch::new("manifest");
    let (store, _) = publish_v1(scratch.path());
    let path = scratch.path().join("manifests/resnet_mini_synth_a/1.json");
    let original = fs::read_to_string(&path).unwrap();

    // Any flipped byte in the wrapper document must fail: either the
    // JSON breaks, or the signature / manifest text no longer match.
    for offset in (0..original.len()).step_by(3) {
        let mut bad = original.clone().into_bytes();
        bad[offset] ^= 0x01;
        fs::write(&path, &bad).unwrap();
        let err = store.fetch("resnet_mini_synth_a", None, &signer()).unwrap_err();
        assert_fatal(&err, &format!("manifest byte {offset}"));
    }
    fs::write(&path, original.as_bytes()).unwrap();
}

#[test]
fn wrong_key_and_wrong_key_id_are_rejected() {
    let scratch = Scratch::new("keys");
    let (store, _) = publish_v1(scratch.path());
    let wrong_key = HmacSha256Signer::new(b"some-other-key".to_vec(), "test-key");
    let err = store.fetch("resnet_mini_synth_a", None, &wrong_key).unwrap_err();
    assert!(matches!(err, Error::Corrupt(_)), "wrong key: {err}");
    let rotated = HmacSha256Signer::new(b"tamper-wall-key".to_vec(), "rotated-key");
    let err = store.fetch("resnet_mini_synth_a", None, &rotated).unwrap_err();
    assert!(matches!(err, Error::Corrupt(_)), "rotated key id: {err}");
}

#[test]
fn stale_and_zero_versions_cannot_publish() {
    let scratch = Scratch::new("stale");
    let (store, manifest) = publish_v1(scratch.path());
    // Same version again → refused, never overwritten.
    let err = store.publish(&manifest, &signer()).unwrap_err();
    assert!(matches!(err, Error::InvalidArg(_)), "{err}");
    assert!(err.to_string().contains("stale"), "{err}");
    // Version 0 is reserved for unversioned serving.
    let mut zero = manifest.clone();
    zero.model_version = 0;
    let err = store.publish(&zero, &signer()).unwrap_err();
    assert!(matches!(err, Error::InvalidArg(_)), "{err}");
    // Moving forward works, and latest-fetch follows.
    let mut v2 = manifest.clone();
    v2.model_version = 2;
    store.publish(&v2, &signer()).unwrap();
    let dep = store.fetch("resnet_mini_synth_a", None, &signer()).unwrap();
    assert_eq!(dep.manifest.model_version, 2);
}

/// Replay attack: a validly-signed v1 wrapper copied over the v2 slot.
/// The signature verifies, but the embedded version disagrees with the
/// slot — classified as version skew, the fatal-until-resync class.
#[test]
fn stale_signed_manifest_in_newer_slot_is_version_skew() {
    let scratch = Scratch::new("replay");
    let (store, manifest) = publish_v1(scratch.path());
    let mut v2 = manifest.clone();
    v2.model_version = 2;
    store.publish(&v2, &signer()).unwrap();
    let dir = scratch.path().join("manifests/resnet_mini_synth_a");
    fs::copy(dir.join("1.json"), dir.join("2.json")).unwrap();
    let err = store.fetch("resnet_mini_synth_a", Some(2), &signer()).unwrap_err();
    assert!(matches!(err, Error::VersionSkew { active: 2, offered: 1, .. }), "{err}");
    assert!(!err.is_retryable());
}

#[test]
fn absent_model_is_a_typed_artifact_error() {
    let scratch = Scratch::new("nomodel");
    let store = ChunkStore::open(scratch.path());
    let err = store.fetch("never_published", None, &signer()).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "{err}");
}

#[test]
fn identical_chunks_are_deduplicated_by_address() {
    let scratch = Scratch::new("dedup");
    let store = ChunkStore::open(scratch.path());
    let bytes = artifact_bytes(0x11, 320);
    let a = store.put_artifact(&bytes, 64).unwrap();
    let b = store.put_artifact(&bytes, 64).unwrap();
    assert_eq!(a, b);
    assert_eq!(chunk_files(scratch.path()).len(), a.chunks.len(), "no duplicate objects");
    assert_eq!(store.read_artifact(&a).unwrap(), bytes);
}

/// Hot-swap under concurrent readers: every snapshot a reader takes is
/// a consistent (version, value) pairing, versions never run backwards,
/// and nothing panics — zero requests lost while versions 2..=6 land.
/// A failed smoke check mid-sequence leaves the active version alone.
#[test]
fn hot_swap_under_concurrent_load_loses_nothing_and_rolls_back() {
    let slot = Arc::new(ModelSlot::new(1u64, 100u64));
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..4)
        .map(|_| {
            let slot = Arc::clone(&slot);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut last = 0u64;
                let mut reads = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let snap = slot.active();
                    // Invariant: value is always version * 100 — a torn
                    // or half-swapped deployment would break it.
                    assert_eq!(snap.value, snap.version * 100, "torn deployment snapshot");
                    assert!(snap.version >= last, "version ran backwards");
                    last = snap.version;
                    reads += 1;
                }
                reads
            })
        })
        .collect();

    for version in 2..=6u64 {
        slot.hot_swap(version, version * 100, |_| Ok(())).unwrap();
        // A bad candidate between good swaps must roll back (by never
        // flipping) while readers keep going.
        let err = slot
            .hot_swap(version + 100, 0, |_| Err(Error::corrupt("smoke decode failed")))
            .unwrap_err();
        assert!(matches!(err, Error::Corrupt(_)));
        assert_eq!(slot.version(), version, "failed swap left the active version");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    let total: u64 = readers.into_iter().map(|r| r.join().unwrap()).sum();
    assert!(total > 0, "readers must have made progress during the swaps");
    assert_eq!(slot.version(), 6);
    assert_eq!(slot.active().value, 600);
}
