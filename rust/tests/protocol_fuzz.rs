//! Fuzz-style robustness tests for the wire protocol and container
//! parsers: arbitrary bytes must never panic, only error. Covers the
//! coordinator frames, both container formats (v1 `RSC1` and chunked
//! v2 `RSC2`), the interleaved stream framing (v1 and v2 multi-state
//! layouts), and the JSON/dataset readers.

use rans_sc::coordinator::protocol::Frame;
use rans_sc::data::{McTask, VisionSet};
use rans_sc::engine::{ChunkedContainer, ContainerFormat, Engine, EngineConfig};
use rans_sc::pipeline::{Container, PipelineConfig};
use rans_sc::rans::FreqTable;
use rans_sc::testutil;
use rans_sc::util::json;

fn random_bytes(rng: &mut rans_sc::util::prng::Rng) -> Vec<u8> {
    let len = rng.below_usize(4096);
    (0..len).map(|_| rng.next_u64() as u8).collect()
}

#[test]
fn fuzz_frame_parser_never_panics() {
    testutil::check(
        "Frame::from_wire on garbage",
        300,
        random_bytes,
        |bytes| {
            // Must return (not panic); almost always Err, and when Ok the
            // reported length must be within the buffer.
            match Frame::from_wire(bytes) {
                Ok((_, used)) => used <= bytes.len(),
                Err(_) => true,
            }
        },
    );
}

#[test]
fn fuzz_container_parser_never_panics() {
    testutil::check("Container::from_bytes on garbage", 300, random_bytes, |bytes| {
        Container::from_bytes(bytes).is_err() || !bytes.is_empty()
    });
}

#[test]
fn fuzz_freq_table_deserialize() {
    testutil::check("FreqTable::deserialize on garbage", 300, random_bytes, |bytes| {
        let mut pos = 0;
        match FreqTable::deserialize(bytes, &mut pos) {
            Ok(t) => t.alphabet() > 0 && pos <= bytes.len(),
            Err(_) => true,
        }
    });
}

#[test]
fn fuzz_dataset_readers() {
    testutil::check("dataset readers on garbage", 200, random_bytes, |bytes| {
        let _ = VisionSet::from_bytes(bytes);
        let _ = McTask::from_bytes(bytes);
        true // reaching here = no panic
    });
}

#[test]
fn fuzz_json_parser() {
    testutil::check(
        "json parser on garbage text",
        300,
        |rng| {
            // Mix of JSON-ish characters to stress structure handling.
            let chars = b"{}[]\",:0123456789.eE+-truefalsn \\\n\x01";
            let len = rng.below_usize(512);
            let bytes: Vec<u8> =
                (0..len).map(|_| chars[rng.below_usize(chars.len())]).collect();
            String::from_utf8_lossy(&bytes).into_owned()
        },
        |text| {
            let _ = json::parse(text);
            true
        },
    );
}

/// A deterministic tensor for the container-mutation fuzzers below.
fn fuzz_tensor(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = rans_sc::util::prng::Rng::new(seed);
    (0..len)
        .map(|_| if rng.next_f64() < 0.5 { 0.0 } else { rng.normal().abs() as f32 })
        .collect()
}

#[test]
fn fuzz_chunked_container_parser_never_panics() {
    testutil::check(
        "ChunkedContainer::from_bytes on garbage",
        300,
        random_bytes,
        |bytes| {
            // Must return (not panic); random bytes essentially never
            // carry the RSC2 magic + a valid header CRC.
            let _ = ChunkedContainer::from_bytes(bytes);
            true
        },
    );
}

#[test]
fn fuzz_interleaved_stream_parser_never_panics() {
    testutil::check(
        "parse_stream_spans on garbage (v1 and v2 headers)",
        300,
        random_bytes,
        |bytes| {
            match rans_sc::rans::interleaved::parse_stream_spans(bytes) {
                // When garbage parses, every lane span must stay inside
                // the buffer (the invariant decode relies on).
                Ok(s) => s.lanes.iter().all(|(_, r)| r.end <= bytes.len()),
                Err(_) => true,
            }
        },
    );
}

/// Every byte of a ChunkedV2 container is covered by either the header
/// CRC or one of the per-chunk CRCs, so *any* single-bit corruption —
/// header fields, chunk table, chunk payloads, the checksums
/// themselves — must be rejected end to end.
#[test]
fn fuzz_mutated_chunked_v2_container_rejected() {
    let engine = Engine::new(EngineConfig {
        workers: 1,
        format: ContainerFormat::ChunkedV2,
        chunk_symbols: 300,
        decode_parallel: None,
    });
    let data = fuzz_tensor(11, 6000);
    let (bytes, _) = engine.compress(&data, &PipelineConfig::paper(4)).unwrap();
    testutil::check(
        "bitflipped ChunkedV2 container",
        200,
        |rng| {
            let mut b = bytes.clone();
            let i = rng.below_usize(b.len());
            b[i] ^= 1 << rng.below(8);
            b
        },
        |b| rans_sc::pipeline::decompress_to_symbols(b).is_err(),
    );
}

/// A v1 container carrying a v2 multi-state payload is covered by the
/// trailing whole-container CRC, so any single-bit corruption — stream
/// marker, states-per-lane, lane framing, state words, renorm bytes —
/// must be rejected before the rANS layer is even reached.
#[test]
fn fuzz_mutated_v2_multistate_container_rejected() {
    for states in [4usize, 8] {
        let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() });
        let data = fuzz_tensor(12 + states as u64, 4096);
        let cfg = PipelineConfig::paper(4).with_states(states);
        let (bytes, _) = engine.compress(&data, &cfg).unwrap();
        testutil::check(
            "bitflipped v2 multi-state container",
            150,
            |rng| {
                let mut b = bytes.clone();
                let i = rng.below_usize(b.len());
                b[i] ^= 1 << rng.below(8);
                b
            },
            |b| rans_sc::pipeline::decompress_to_symbols(b).is_err(),
        );
    }
}

/// Corrupt v2 *stream headers* behind a freshly recomputed container
/// CRC: only the stream-level validation is left to object, and it must
/// do so without panicking (the decode either errors or returns symbols
/// that differ from the original tensor's).
#[test]
fn fuzz_v2_stream_header_garbage_behind_valid_crc() {
    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() });
    let data = fuzz_tensor(13, 4096);
    let cfg = PipelineConfig::paper(4).with_states(4);
    let (bytes, _) = engine.compress(&data, &cfg).unwrap();
    let (symbols, _) = engine.decompress_to_symbols(&bytes).unwrap();
    testutil::check(
        "garbled v2 stream header, CRC fixed up",
        150,
        |rng| {
            let mut c = Container::from_bytes(&bytes).unwrap();
            // Garble 1–4 bytes somewhere in the stream's leading header
            // region (marker, states, lane count, lengths).
            let span = c.payload.len().min(16);
            for _ in 0..1 + rng.below_usize(4) {
                let i = rng.below_usize(span);
                c.payload[i] = rng.next_u64() as u8;
            }
            c.to_bytes() // fresh CRC over the garbled payload
        },
        |garbled| match rans_sc::pipeline::decompress_to_symbols(garbled) {
            Err(_) => true,
            Ok((back, _)) => back != symbols || *garbled == bytes,
        },
    );
}

/// Dtype-tagged headers (RSC1 version 2 / RSC2 version 3): every
/// truncation point must produce a clean error from both the symbol
/// decoder and `decompress_into` — including cuts inside the
/// one-byte-longer dtyped header region — and any single-bit flip is
/// still CRC-rejected (the dtype byte sits under the same checksums as
/// the rest of the header).
#[test]
fn fuzz_truncated_and_mutated_dtyped_headers() {
    use rans_sc::tensor::{half, TensorMut, TensorRef};

    let data = fuzz_tensor(14, 3000);
    let bf16: Vec<u16> = data.iter().map(|&x| half::f32_to_bf16(x)).collect();
    let v1 = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() });
    let v2 = Engine::new(EngineConfig {
        workers: 1,
        format: ContainerFormat::ChunkedV2,
        chunk_symbols: 500,
        decode_parallel: None,
    });
    let cfg = PipelineConfig::paper(4);
    for engine in [&v1, &v2] {
        let (bytes, _) =
            engine.compress_tensor(TensorRef::from_bf16_bits(&bf16), &cfg).unwrap();
        // Version byte + dtype tag present as expected.
        assert!(bytes[4] == 2 || bytes[4] == 3);
        assert_eq!(bytes[6], rans_sc::tensor::Dtype::Bf16.tag());
        // Every truncation errors — exhaustive over the header region,
        // sampled beyond it.
        let cuts = (0..64.min(bytes.len()))
            .chain([bytes.len() / 2, bytes.len() - 1]);
        for cut in cuts {
            assert!(
                engine.decompress_to_symbols(&bytes[..cut]).is_err(),
                "cut {cut} undetected"
            );
            let mut out = vec![0u16; data.len()];
            assert!(
                engine
                    .decompress_into(&bytes[..cut], TensorMut::from_bf16_bits(&mut out))
                    .is_err(),
                "decompress_into cut {cut} undetected"
            );
        }
        // Bitflips anywhere (dtype byte included) are rejected.
        testutil::check(
            "bitflipped dtyped container",
            150,
            |rng| {
                let mut b = bytes.clone();
                let i = rng.below_usize(b.len());
                b[i] ^= 1 << rng.below(8);
                b
            },
            |b| engine.decompress_to_symbols(b).is_err(),
        );
    }
}

/// Versioned frames (model-version header, tag 15) and the
/// `VersionSkew` reply (kind 16) sit under the same body CRC as
/// everything else: any single-bit flip anywhere — length prefix,
/// headers, skew payload, the CRC itself — must be rejected.
#[test]
fn fuzz_mutated_versioned_frames() {
    use rans_sc::coordinator::protocol::FrameKind;
    testutil::check(
        "mutated versioned frames",
        200,
        |rng| {
            let frame = if rng.below(2) == 0 {
                Frame::new(
                    rng.next_u64(),
                    FrameKind::InferVision {
                        model: "m".into(),
                        sl: rng.below_usize(5),
                        batch: 1 + rng.below_usize(8),
                        payload: (0..rng.below_usize(128))
                            .map(|_| rng.next_u64() as u8)
                            .collect(),
                    },
                )
                .with_deadline(1 + rng.below(10_000) as u32)
                .with_model_version(1 + rng.next_u64() % 1000)
            } else {
                Frame::new(
                    rng.next_u64(),
                    FrameKind::VersionSkew {
                        active: 1 + rng.next_u64() % 1000,
                        offered: rng.next_u64() % 1000,
                        message: "resync from registry".into(),
                    },
                )
            };
            let mut wire = frame.to_wire();
            let pos = rng.below_usize(wire.len());
            wire[pos] ^= 1 << rng.below(8);
            wire
        },
        |wire| Frame::from_wire(wire).is_err(),
    );
}

/// Every truncation point of a versioned frame — including cuts inside
/// the model-version header and the skew payload — errors cleanly.
#[test]
fn fuzz_truncated_versioned_frames() {
    use rans_sc::coordinator::protocol::FrameKind;
    for frame in [
        Frame::new(7, FrameKind::Ping).with_deadline(250).with_model_version(3),
        Frame::new(
            8,
            FrameKind::VersionSkew { active: 9, offered: 3, message: "stale".into() },
        ),
    ] {
        let wire = frame.to_wire();
        for cut in 0..wire.len() {
            assert!(Frame::from_wire(&wire[..cut]).is_err(), "cut {cut} undetected");
        }
        let (back, used) = Frame::from_wire(&wire).unwrap();
        assert_eq!(used, wire.len());
        assert_eq!(back, frame);
    }
}

/// Garble the header region *behind a recomputed CRC*: only the header
/// loop's own validation is left to object. The parse must never panic,
/// and when it errors the message is a typed framing error (nested /
/// truncated header, unknown kind) — never a silent misparse of the
/// model version into something else.
#[test]
fn fuzz_versioned_header_garbage_behind_valid_crc() {
    use rans_sc::coordinator::protocol::FrameKind;
    use rans_sc::util::crc32;
    let frame = Frame::new(42, FrameKind::Ping).with_deadline(100).with_model_version(5);
    let wire = frame.to_wire();
    let body_len = wire.len() - 8;
    testutil::check(
        "garbled frame headers, CRC fixed up",
        300,
        |rng| {
            let mut body = wire[4..4 + body_len].to_vec();
            // Garble 1–3 bytes in the header region (after request_id).
            for _ in 0..1 + rng.below_usize(3) {
                let i = 8 + rng.below_usize(body.len() - 8);
                body[i] = rng.next_u64() as u8;
            }
            let mut out = (body.len() as u32).to_le_bytes().to_vec();
            out.extend_from_slice(&body);
            out.extend_from_slice(&crc32::hash(&body).to_le_bytes());
            out
        },
        |garbled| match Frame::from_wire(garbled) {
            Err(e) => {
                matches!(e, rans_sc::error::Error::Protocol(_) | rans_sc::error::Error::Corrupt(_))
            }
            // If it still parses, the headers must decode to *some*
            // consistent frame that round-trips.
            Ok((f, used)) => used == garbled.len() && Frame::from_wire(&f.to_wire()).is_ok(),
        },
    );
}

#[test]
fn fuzz_mutated_valid_frames() {
    // Start from valid frames, flip a byte: parser must reject or
    // produce a different frame, never panic.
    use rans_sc::coordinator::protocol::FrameKind;
    testutil::check(
        "mutated valid frames",
        200,
        |rng| {
            let frame = Frame::new(
                rng.next_u64(),
                FrameKind::InferVision {
                    model: "m".into(),
                    sl: rng.below_usize(5),
                    batch: 1 + rng.below_usize(8),
                    payload: (0..rng.below_usize(256)).map(|_| rng.next_u64() as u8).collect(),
                },
            );
            let mut wire = frame.to_wire();
            let pos = rng.below_usize(wire.len());
            wire[pos] ^= 1 << rng.below(8);
            wire
        },
        |wire| Frame::from_wire(wire).is_err(),
    );
}
