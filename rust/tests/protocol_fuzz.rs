//! Fuzz-style robustness tests for the wire protocol and container
//! parsers: arbitrary bytes must never panic, only error.

use rans_sc::coordinator::protocol::Frame;
use rans_sc::data::{McTask, VisionSet};
use rans_sc::pipeline::Container;
use rans_sc::rans::FreqTable;
use rans_sc::testutil;
use rans_sc::util::json;

fn random_bytes(rng: &mut rans_sc::util::prng::Rng) -> Vec<u8> {
    let len = rng.below_usize(4096);
    (0..len).map(|_| rng.next_u64() as u8).collect()
}

#[test]
fn fuzz_frame_parser_never_panics() {
    testutil::check(
        "Frame::from_wire on garbage",
        300,
        random_bytes,
        |bytes| {
            // Must return (not panic); almost always Err, and when Ok the
            // reported length must be within the buffer.
            match Frame::from_wire(bytes) {
                Ok((_, used)) => used <= bytes.len(),
                Err(_) => true,
            }
        },
    );
}

#[test]
fn fuzz_container_parser_never_panics() {
    testutil::check("Container::from_bytes on garbage", 300, random_bytes, |bytes| {
        Container::from_bytes(bytes).is_err() || !bytes.is_empty()
    });
}

#[test]
fn fuzz_freq_table_deserialize() {
    testutil::check("FreqTable::deserialize on garbage", 300, random_bytes, |bytes| {
        let mut pos = 0;
        match FreqTable::deserialize(bytes, &mut pos) {
            Ok(t) => t.alphabet() > 0 && pos <= bytes.len(),
            Err(_) => true,
        }
    });
}

#[test]
fn fuzz_dataset_readers() {
    testutil::check("dataset readers on garbage", 200, random_bytes, |bytes| {
        let _ = VisionSet::from_bytes(bytes);
        let _ = McTask::from_bytes(bytes);
        true // reaching here = no panic
    });
}

#[test]
fn fuzz_json_parser() {
    testutil::check(
        "json parser on garbage text",
        300,
        |rng| {
            // Mix of JSON-ish characters to stress structure handling.
            let chars = b"{}[]\",:0123456789.eE+-truefalsn \\\n\x01";
            let len = rng.below_usize(512);
            let bytes: Vec<u8> =
                (0..len).map(|_| chars[rng.below_usize(chars.len())]).collect();
            String::from_utf8_lossy(&bytes).into_owned()
        },
        |text| {
            let _ = json::parse(text);
            true
        },
    );
}

#[test]
fn fuzz_mutated_valid_frames() {
    // Start from valid frames, flip a byte: parser must reject or
    // produce a different frame, never panic.
    use rans_sc::coordinator::protocol::FrameKind;
    testutil::check(
        "mutated valid frames",
        200,
        |rng| {
            let frame = Frame {
                request_id: rng.next_u64(),
                kind: FrameKind::InferVision {
                    model: "m".into(),
                    sl: rng.below_usize(5),
                    batch: 1 + rng.below_usize(8),
                    payload: (0..rng.below_usize(256)).map(|_| rng.next_u64() as u8).collect(),
                },
            };
            let mut wire = frame.to_wire();
            let pos = rng.below_usize(wire.len());
            wire[pos] ^= 1 << rng.below(8);
            wire
        },
        |wire| Frame::from_wire(wire).is_err(),
    );
}
