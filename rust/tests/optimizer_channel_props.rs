//! Property tests on the reshape optimizer, the channel model, the JSON
//! substrate and the tANS baseline (no artifacts required).

use rans_sc::channel::{ChannelParams, OutageChannel};
use rans_sc::quant::{quantize, QuantParams};
use rans_sc::rans::FreqTable;
use rans_sc::reshape::{self, optimizer::OptimizerConfig};
use rans_sc::tans::{tans_decode, tans_encode};
use rans_sc::testutil;
use rans_sc::util::json::{self, ObjBuilder, Value};
use rans_sc::util::prng::Rng;

fn gen_symbols(rng: &mut Rng) -> (Vec<u16>, u8, u16) {
    let q = *rng.choose(&[2u8, 3, 4, 6, 8]);
    let len = 64 + rng.below_usize(8000);
    let sparsity = 0.3 + rng.next_f64() * 0.6;
    let data: Vec<f32> = (0..len)
        .map(|_| if rng.next_f64() < sparsity { 0.0 } else { rng.normal().abs() as f32 })
        .collect();
    let params = QuantParams::fit(q, &data).unwrap();
    (quantize(&data, &params), q, params.zero_symbol())
}

#[test]
fn prop_optimizer_choice_in_constrained_domain() {
    testutil::check(
        "Ñ satisfies N|T, N>√T (when feasible), K ≤ 2^Q",
        40,
        |rng| gen_symbols(rng),
        |(symbols, q, bg)| {
            let cfg = OptimizerConfig::paper(*q);
            let out = match reshape::optimize(symbols, *bg, &cfg) {
                Ok(o) => o,
                Err(_) => return false,
            };
            let t = symbols.len();
            let n = out.best.n;
            t % n == 0 && t / n <= (1usize << q) && out.evaluated <= out.domain_size
        },
    );
}

#[test]
fn prop_optimizer_never_beats_oracle() {
    testutil::check(
        "T_tot(Ñ) ≥ T_tot(N*) and within 10%",
        25,
        |rng| gen_symbols(rng),
        |(symbols, q, bg)| {
            let cfg = OptimizerConfig::paper(*q);
            let a = reshape::optimize(symbols, *bg, &cfg);
            let o = reshape::exhaustive_search(symbols, *bg, &cfg, true);
            match (a, o) {
                (Ok(a), Ok(o)) => {
                    a.best.t_tot_bits >= o.best.t_tot_bits - 1e-9
                        && a.best.t_tot_bits <= o.best.t_tot_bits.max(1.0) * 1.10 + 64.0
                }
                _ => false,
            }
        },
    );
}

#[test]
fn prop_channel_latency_monotone_in_size_and_snr() {
    testutil::check(
        "T_comm monotone: more bytes slower, more SNR faster",
        60,
        |rng| {
            let gamma = rng.next_f64() * 30.0;
            let bytes = 1 + rng.below_usize(1 << 22);
            (gamma, bytes)
        },
        |(gamma, bytes)| {
            let ch = OutageChannel::new(ChannelParams { gamma_db: *gamma, ..Default::default() })
                .unwrap();
            let ch_hi =
                OutageChannel::new(ChannelParams { gamma_db: gamma + 3.0, ..Default::default() })
                    .unwrap();
            ch.comm_latency_s(*bytes) < ch.comm_latency_s(bytes + 1000)
                && ch_hi.comm_latency_s(*bytes) < ch.comm_latency_s(*bytes)
        },
    );
}

#[test]
fn prop_tans_roundtrip_random_tables() {
    testutil::check(
        "tANS roundtrip over random distributions",
        25,
        |rng| {
            let alphabet = 2 + rng.below_usize(128);
            let skew = 0.3 + rng.next_f64() * 2.0;
            let len = rng.below_usize(4000);
            let symbols: Vec<u32> =
                (0..len).map(|_| rng.zipf(alphabet, skew) as u32).collect();
            (symbols, alphabet)
        },
        |(symbols, alphabet)| {
            let table = FreqTable::from_symbols(symbols, *alphabet);
            match tans_encode(symbols, &table)
                .and_then(|b| tans_decode(&b, symbols.len(), &table))
            {
                Ok(back) => back == *symbols,
                Err(_) => false,
            }
        },
    );
}

fn gen_json_value(rng: &mut Rng, depth: usize) -> Value {
    match if depth > 3 { rng.below(4) } else { rng.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.next_f64() < 0.5),
        2 => Value::Num((rng.next_u64() % 1_000_000) as f64 - 500_000.0),
        3 => {
            let len = rng.below_usize(12);
            Value::Str(
                (0..len)
                    .map(|_| char::from_u32(32 + rng.next_u64() as u32 % 90).unwrap())
                    .collect(),
            )
        }
        4 => Value::Arr((0..rng.below_usize(5)).map(|_| gen_json_value(rng, depth + 1)).collect()),
        _ => {
            let mut b = ObjBuilder::new();
            for i in 0..rng.below_usize(5) {
                b = b.field(&format!("k{i}"), gen_json_value(rng, depth + 1));
            }
            b.build()
        }
    }
}

#[test]
fn prop_json_roundtrip_random_trees() {
    testutil::check(
        "json parse ∘ write = id",
        120,
        |rng| gen_json_value(rng, 0),
        |v| {
            let compact = json::parse(&v.to_string_compact());
            let pretty = json::parse(&v.to_string_pretty());
            compact.as_ref().ok() == Some(v) && pretty.as_ref().ok() == Some(v)
        },
    );
}
