//! Engine integration tests: concurrent round-trips through one shared
//! engine, byte-identity with the serial pipeline path, and chunked-v2
//! corruption rejection.

use std::sync::Arc;

use rans_sc::engine::{ChunkedContainer, ContainerFormat, Engine, EngineConfig};
use rans_sc::pipeline::{self, PipelineConfig, ReshapeStrategy, StreamLayout};
use rans_sc::quant::{quantize, QuantParams};
use rans_sc::util::prng::Rng;

fn synth_tensor(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..len)
        .map(|_| if rng.next_f64() < 0.55 { 0.0 } else { rng.normal().abs() as f32 * 1.5 })
        .collect()
}

/// The pre-refactor serial reference: the exact pipeline stages the old
/// `pipeline::codec::compress_quantized` ran inline, reproduced from
/// primitives. The engine's v1 output must match this byte-for-byte.
fn serial_reference(symbols: &[u16], params: QuantParams, cfg: &PipelineConfig) -> Vec<u8> {
    use rans_sc::pipeline::Container;
    use rans_sc::rans::{encode_interleaved, FreqTable};
    use rans_sc::sparse::ModCsr;
    use rans_sc::util::stats;

    let background = params.zero_symbol();
    let n_rows = match cfg.reshape {
        ReshapeStrategy::Fixed(n) => n,
        _ => panic!("reference path expects Fixed"),
    };
    let k = symbols.len() / n_rows;
    let csr = ModCsr::encode(symbols, n_rows, k, background).unwrap();
    let d = csr.concat();
    let alphabet = csr.concat_alphabet(params.alphabet());
    let freqs = stats::histogram(&d, alphabet);
    let table = if d.is_empty() {
        FreqTable::from_symbols(&d, alphabet)
    } else {
        FreqTable::from_counts(&freqs).unwrap()
    };
    let payload = encode_interleaved(&d, &table, cfg.lanes, false).unwrap();
    Container {
        dtype: rans_sc::tensor::Dtype::F32,
        params,
        orig_len: symbols.len(),
        n_rows,
        nnz: csr.nnz(),
        alphabet,
        table,
        payload,
    }
    .to_bytes()
}

#[test]
fn engine_bytes_identical_to_serial_reference() {
    let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() });
    let data = synth_tensor(11, 12_800);
    for q in [2u8, 3, 4, 6, 8] {
        let params = QuantParams::fit(q, &data).unwrap();
        let symbols = quantize(&data, &params);
        // Pin the reshape so the reference path needs no optimizer.
        let (_, probe) = engine
            .compress_quantized(&symbols, params, &PipelineConfig::paper(q))
            .unwrap();
        for lanes in [1usize, 4, 8] {
            let cfg = PipelineConfig {
                q,
                lanes,
                parallel: true,
                reshape: ReshapeStrategy::Fixed(probe.n_rows),
                layout: StreamLayout::V1,
            };
            let (engine_bytes, _) = engine.compress_quantized(&symbols, params, &cfg).unwrap();
            let reference = serial_reference(&symbols, params, &cfg);
            assert_eq!(engine_bytes, reference, "q={q} lanes={lanes}");
        }
    }
}

#[test]
fn pipeline_wrappers_route_through_shared_engine() {
    // The public pipeline API must keep its exact contract: roundtrip,
    // v1 magic, and byte-stability across repeated calls.
    let data = synth_tensor(12, 8192);
    let cfg = PipelineConfig::paper(4);
    let (a, stats) = pipeline::compress(&data, &cfg).unwrap();
    let (b, _) = pipeline::compress(&data, &cfg).unwrap();
    assert_eq!(a, b);
    assert_eq!(&a[0..4], b"RSC1");
    assert_eq!(stats.total_bytes, a.len());
    let back = pipeline::decompress(&a).unwrap();
    assert_eq!(back.len(), data.len());
}

#[test]
fn concurrent_roundtrips_through_one_shared_engine() {
    // Many threads compressing/decompressing *distinct* tensors through
    // one engine: results must be exact and byte-identical to what the
    // same engine produces serially (no cross-request state bleed).
    let engine = Arc::new(Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() }));
    let n_threads = 8usize;
    let per_thread = 6usize;

    std::thread::scope(|s| {
        for t in 0..n_threads {
            let engine = Arc::clone(&engine);
            s.spawn(move || {
                for i in 0..per_thread {
                    let seed = (t * 1000 + i) as u64 + 1;
                    let len = 2048 + 512 * (i % 3);
                    let data = synth_tensor(seed, len);
                    let q = [2u8, 4, 6][i % 3];
                    let params = QuantParams::fit(q, &data).unwrap();
                    let symbols = quantize(&data, &params);
                    let par = PipelineConfig {
                        q,
                        lanes: 8,
                        parallel: true,
                        reshape: ReshapeStrategy::Optimize,
                        // Exercise all stream layouts (and with them the
                        // SIMD decode dispatch) under concurrency.
                        layout: match i % 3 {
                            0 => StreamLayout::V1,
                            1 => StreamLayout::MultiState(4),
                            _ => StreamLayout::MultiState(8),
                        },
                    };
                    let ser = PipelineConfig { parallel: false, ..par.clone() };
                    let (bytes_par, _) =
                        engine.compress_quantized(&symbols, params, &par).unwrap();
                    let (bytes_ser, _) =
                        engine.compress_quantized(&symbols, params, &ser).unwrap();
                    assert_eq!(
                        bytes_par, bytes_ser,
                        "thread {t} item {i}: pooled vs serial bytes diverged"
                    );
                    let (back, back_params) =
                        engine.decompress_to_symbols(&bytes_par).unwrap();
                    assert_eq!(back, symbols, "thread {t} item {i}");
                    assert_eq!(back_params, params);
                }
            });
        }
    });
}

#[test]
fn concurrent_v2_roundtrips() {
    let engine = Arc::new(Engine::new(EngineConfig {
        workers: 4,
        format: ContainerFormat::ChunkedV2,
        chunk_symbols: 700,
        decode_parallel: None,
    }));
    std::thread::scope(|s| {
        for t in 0..6usize {
            let engine = Arc::clone(&engine);
            s.spawn(move || {
                let data = synth_tensor(100 + t as u64, 6000 + t * 128);
                let params = QuantParams::fit(4, &data).unwrap();
                let symbols = quantize(&data, &params);
                let (bytes, _) = engine
                    .compress_quantized(&symbols, params, &PipelineConfig::paper(4))
                    .unwrap();
                let (back, _) = engine.decompress_to_symbols(&bytes).unwrap();
                assert_eq!(back, symbols, "thread {t}");
            });
        }
    });
}

#[test]
fn chunked_v2_every_byte_flip_rejected() {
    let engine = Engine::new(EngineConfig {
        workers: 2,
        format: ContainerFormat::ChunkedV2,
        chunk_symbols: 400,
        decode_parallel: None,
    });
    let data = synth_tensor(21, 3000);
    let (bytes, _) = engine.compress(&data, &PipelineConfig::paper(4)).unwrap();
    let parsed = ChunkedContainer::from_bytes(&bytes).unwrap();
    assert!(parsed.chunks.len() > 1, "need multiple chunks for this test");

    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0x40;
        assert!(
            engine.decompress_to_symbols(&bad).is_err(),
            "flip at byte {i} undetected"
        );
    }
}

#[test]
fn chunked_v2_truncation_rejected() {
    let engine = Engine::new(EngineConfig {
        workers: 2,
        format: ContainerFormat::ChunkedV2,
        chunk_symbols: 512,
        decode_parallel: None,
    });
    let data = synth_tensor(22, 4096);
    let (bytes, _) = engine.compress(&data, &PipelineConfig::paper(4)).unwrap();
    for cut in [0, 3, 16, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            engine.decompress_to_symbols(&bytes[..cut]).is_err(),
            "cut at {cut} undetected"
        );
    }
}

#[test]
fn chunked_v2_partial_decode_survives_unrelated_corruption() {
    // Streaming property: a flipped byte in the last chunk leaves every
    // earlier chunk independently decodable and verifiable.
    let engine = Engine::new(EngineConfig {
        workers: 2,
        format: ContainerFormat::ChunkedV2,
        chunk_symbols: 300,
        decode_parallel: None,
    });
    let data = synth_tensor(23, 4000);
    let (mut bytes, _) = engine.compress(&data, &PipelineConfig::paper(4)).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    let parsed = ChunkedContainer::from_bytes(&bytes).unwrap();
    let n = parsed.chunks.len();
    assert!(n >= 2);
    for i in 0..n - 1 {
        parsed.decode_chunk(i).unwrap();
    }
    assert!(parsed.decode_chunk(n - 1).is_err());
}

#[test]
fn edge_plan_cache_type_still_reachable_from_coordinator() {
    // The PlanCache moved into the engine; the coordinator re-export must
    // keep the old path working for downstream users.
    let cache = rans_sc::coordinator::edge::PlanCache::default();
    let data = synth_tensor(31, 2048);
    let params = QuantParams::fit(4, &data).unwrap();
    let symbols = quantize(&data, &params);
    let strat = cache.strategy(&symbols, &params).unwrap();
    assert!(matches!(strat, ReshapeStrategy::Fixed(_)));
    assert_eq!(cache.stats().1, 1);
}
