//! Chaos soak: the resilient session layer against a deterministic
//! hostile link.
//!
//! Thousands of requests are driven through [`Session`] over a
//! [`FaultyTransport`] that drops, corrupts, duplicates, truncates and
//! delays wire frames from a seeded schedule, while the responder sheds
//! a deterministic subset of requests with `Busy`. The contract under
//! test is the tentpole's acceptance bar:
//!
//! * **zero hangs** — an in-process watchdog aborts the test if a run
//!   wedges, and each run asserts a wall-clock ceiling;
//! * **zero panics** — any panic fails the test on its own;
//! * **every outcome is explicit** — a verified correct reply, a clean
//!   retryable error, or an explicit `Rejected` shed. Nothing else.
//!
//! CI shards the soak with `RANS_SC_CHAOS_FAULT` (one fault family) and
//! `RANS_SC_CHAOS_SEED`; run without either and every family × two
//! seeds executes (≥ 2,000 requests total). `RANS_SC_CHAOS_REQUESTS`
//! scales the per-run volume.
//!
//! The **daemon fault family** turns the same chaos schedules against
//! the actor serving daemon: whole synthetic fleets of concurrent
//! chaos-linked edges against one daemon (no silent drops at fleet
//! scale), and a noisy tenant hammering a tiny quota while a quiet
//! tenant must keep flowing. `RANS_SC_CHAOS_FAULT` shards these too.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use rans_sc::coordinator::{
    FaultSpec, FaultyTransport, Frame, FrameKind, Session, SessionConfig, Transport,
};
use rans_sc::error::Error;
use rans_sc::telemetry::Registry;

/// First payload byte marking a request the responder must always shed.
const SHED_MARK: u8 = 0xFF;

/// Abort the whole process if `done` is not raised within `secs` — the
/// soak's hang guard (a wedged channel or sleep would otherwise stall
/// the harness until an external timeout).
fn arm_watchdog(secs: u64, done: Arc<AtomicBool>) {
    thread::spawn(move || {
        for _ in 0..secs {
            thread::sleep(Duration::from_secs(1));
            if done.load(Ordering::Relaxed) {
                return;
            }
        }
        eprintln!("chaos soak watchdog fired after {secs}s — aborting");
        std::process::abort();
    });
}

/// The reply value the responder computes for a payload; the client
/// recomputes it to verify end-to-end integrity of every `Ok` outcome.
fn checksum(payload: &[u8]) -> f32 {
    let sum: u64 = payload.iter().map(|&b| b as u64).sum();
    sum as f32 + payload.len() as f32 * 0.5
}

/// Deterministic per-request payload. Requests with `i % 17 == 0` carry
/// the shed mark; all others are guaranteed not to.
fn payload_for(i: usize) -> Vec<u8> {
    let len = 1 + (i % 97);
    let mut p: Vec<u8> = (0..len).map(|j| ((i * 31 + j * 7) & 0xFF) as u8).collect();
    if i % 17 == 0 {
        p[0] = SHED_MARK;
    } else if p[0] == SHED_MARK {
        p[0] = 0;
    }
    p
}

/// Minimal cloud stand-in on the far end of a faulty link. Parse
/// failures from injected faults are skipped (a real server would log
/// and move on); a closed peer ends the thread.
fn responder(mut t: FaultyTransport) {
    loop {
        let frame = match t.recv() {
            Ok(f) => f,
            Err(e) if e.to_string().contains("injected link fault") => continue,
            Err(_) => return, // peer closed
        };
        let reply = match frame.kind {
            FrameKind::Ping => FrameKind::Pong,
            FrameKind::Shutdown => return,
            FrameKind::InferLm { ref payload, .. } => {
                if payload.first() == Some(&SHED_MARK) {
                    FrameKind::Busy { retry_after_ms: 1, message: "soak overload".into() }
                } else {
                    FrameKind::Logits {
                        data: vec![checksum(payload)],
                        decode_ms: 0.0,
                        compute_ms: 0.0,
                    }
                }
            }
            other => FrameKind::ServerError { message: format!("unexpected {other:?}") },
        };
        if t.send(&Frame::new(frame.request_id, reply)).is_err() {
            return;
        }
    }
}

/// Outcome tallies for one (family, seed) run.
#[derive(Debug, Default)]
struct Tally {
    ok: usize,
    rejected: usize,
    retryable_err: usize,
}

/// Drive `n` requests through a session whose link (both directions)
/// injects `spec`-shaped faults seeded by `seed`. Every reconnect dials
/// a fresh faulty pair and hands the far end to a new responder.
fn run_soak(family: &str, seed: u64, n: usize, spec: FaultSpec) -> Tally {
    let registry = Arc::new(Registry::new());
    let (hand_tx, hand_rx) = mpsc::channel::<FaultyTransport>();
    let spawner = thread::spawn(move || {
        for t in hand_rx {
            thread::spawn(move || responder(t));
        }
    });
    let pair_seed = Arc::new(AtomicU64::new(seed));
    let mut dial: Box<dyn FnMut() -> rans_sc::error::Result<FaultyTransport> + Send> = {
        let pair_seed = Arc::clone(&pair_seed);
        Box::new(move || {
            let s = pair_seed.fetch_add(1, Ordering::Relaxed);
            let (client, server) = FaultyTransport::pair(s, spec, spec);
            hand_tx
                .send(server)
                .map_err(|_| Error::transport("responder spawner gone"))?;
            Ok(client)
        })
    };
    let cfg = SessionConfig {
        deadline_ms: 4_000,
        try_timeout_ms: 60,
        max_retries: 20,
        base_backoff_ms: 1,
        max_backoff_ms: 8,
        heartbeat_ms: 0,
        seed,
    };
    let first = dial().expect("initial dial cannot fail");
    let mut session = Session::new(first, cfg)
        .with_metrics(Arc::clone(&registry))
        .with_connector(dial);

    let started = Instant::now();
    let mut tally = Tally::default();
    for i in 0..n {
        let payload = payload_for(i);
        let flagged = payload[0] == SHED_MARK;
        let kind = if !flagged && i % 5 == 0 {
            FrameKind::Ping
        } else {
            FrameKind::InferLm { model: "soak".into(), payload: payload.clone() }
        };
        let want_pong = matches!(kind, FrameKind::Ping);
        match session.call(kind) {
            Ok(reply) => {
                assert!(!flagged, "req {i}: shed-marked request must never succeed");
                match reply.kind {
                    FrameKind::Pong => assert!(want_pong, "req {i}: unsolicited Pong"),
                    FrameKind::Logits { ref data, .. } => {
                        assert!(!want_pong, "req {i}: Logits for a Ping");
                        assert_eq!(data.len(), 1, "req {i}");
                        assert_eq!(data[0], checksum(&payload), "req {i}: reply integrity");
                    }
                    ref other => panic!("req {i}: unexpected reply kind {other:?}"),
                }
                tally.ok += 1;
            }
            Err(e @ Error::Rejected { .. }) => {
                assert!(e.is_retryable(), "req {i}: shed must stay retryable ({e})");
                tally.rejected += 1;
            }
            Err(e) => {
                assert!(
                    e.is_retryable(),
                    "req {i} under '{family}' faults: non-retryable error escaped: {e}"
                );
                tally.retryable_err += 1;
            }
        }
    }
    let elapsed = started.elapsed();
    assert!(
        elapsed < Duration::from_secs(180),
        "'{family}' seed {seed}: {n} requests took {elapsed:?} — treating as a hang"
    );

    // The session must have survived *through* retries, not around them.
    assert!(tally.ok >= n / 2, "'{family}' seed {seed}: too few successes: {tally:?}");
    assert!(
        registry.get("session.retry_total") > 0,
        "'{family}' seed {seed}: fault schedule produced no retries"
    );
    let snapshot = registry.snapshot_json();
    for key in ["session.retry_total", "session.attempt_ms"] {
        assert!(snapshot.contains(key), "metrics snapshot lost {key}: {snapshot}");
    }
    drop(session); // hangs up: responders and the spawner drain out
    spawner.join().unwrap();
    tally
}

fn fault_families() -> Vec<(&'static str, FaultSpec)> {
    vec![
        ("drop", FaultSpec::drops(0.25)),
        ("corrupt", FaultSpec::corruption(0.25)),
        ("delay", FaultSpec::delays(0.6, Duration::from_millis(4))),
        ("disconnect", FaultSpec::truncations(0.2)),
        ("duplicate", FaultSpec::duplicates(0.3)),
    ]
}

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

#[test]
fn chaos_soak_every_outcome_is_explicit() {
    let done = Arc::new(AtomicBool::new(false));
    arm_watchdog(480, Arc::clone(&done));

    let only_family = std::env::var("RANS_SC_CHAOS_FAULT").ok();
    let seeds: Vec<u64> =
        env_u64("RANS_SC_CHAOS_SEED").map(|s| vec![s]).unwrap_or_else(|| vec![1, 2]);
    let n = env_u64("RANS_SC_CHAOS_REQUESTS").unwrap_or(200) as usize;

    let families: Vec<_> = fault_families()
        .into_iter()
        .filter(|(name, _)| only_family.as_deref().map(|f| f == *name).unwrap_or(true))
        .collect();
    assert!(
        !families.is_empty(),
        "RANS_SC_CHAOS_FAULT={only_family:?} matches no fault family"
    );

    let mut total = Tally::default();
    for &(name, spec) in &families {
        for &seed in &seeds {
            let t = run_soak(name, seed, n, spec);
            println!("soak '{name}' seed {seed}: {t:?}");
            total.ok += t.ok;
            total.rejected += t.rejected;
            total.retryable_err += t.retryable_err;
            // On a link where replies always arrive (delays only bound
            // latency), a shed-marked request deterministically burns
            // its retry budget on Busy and surfaces as Rejected.
            if name == "delay" {
                assert!(t.rejected > 0, "delay seed {seed}: no explicit Rejected: {t:?}");
            }
        }
    }
    println!(
        "soak total over {} requests: {total:?}",
        families.len() * seeds.len() * n
    );
    done.store(true, Ordering::Relaxed);
}

/// Versioned cloud stand-in: requests carrying a model-version header
/// that disagrees with `active` get a `VersionSkew` reply; everything
/// else is served normally. Injected-fault parse errors are skipped,
/// as in [`responder`].
fn versioned_responder(mut t: FaultyTransport, active: Arc<AtomicU64>) {
    loop {
        let frame = match t.recv() {
            Ok(f) => f,
            Err(e) if e.to_string().contains("injected link fault") => continue,
            Err(_) => return, // peer closed
        };
        let now = active.load(Ordering::Relaxed);
        let reply = match (frame.model_version, &frame.kind) {
            (Some(v), _) if v != now => FrameKind::VersionSkew {
                active: now,
                offered: v,
                message: "deployment flipped mid-soak; resync from the registry".into(),
            },
            (_, FrameKind::InferLm { payload, .. }) => FrameKind::Logits {
                data: vec![checksum(payload)],
                decode_ms: 0.0,
                compute_ms: 0.0,
            },
            (_, FrameKind::Ping) => FrameKind::Pong,
            (_, other) => FrameKind::ServerError { message: format!("unexpected {other:?}") },
        };
        if t.send(&Frame::new(frame.request_id, reply)).is_err() {
            return;
        }
    }
}

/// The version-flip fault family: the cloud hot-swaps deployments twice
/// while a pinned session keeps calling over a lossy link. Every skew
/// must resolve through the resync hook *within the affected call* —
/// resync, never hang, never a silently mis-decoded reply — and the
/// session must end the run pinned to the final deployment.
#[test]
fn version_flip_mid_soak_resyncs_instead_of_hanging() {
    let done = Arc::new(AtomicBool::new(false));
    arm_watchdog(240, Arc::clone(&done));

    let active = Arc::new(AtomicU64::new(1));
    let registry = Arc::new(Registry::new());
    let (hand_tx, hand_rx) = mpsc::channel::<FaultyTransport>();
    let spawner = {
        let active = Arc::clone(&active);
        thread::spawn(move || {
            for t in hand_rx {
                let active = Arc::clone(&active);
                thread::spawn(move || versioned_responder(t, active));
            }
        })
    };
    let pair_seed = Arc::new(AtomicU64::new(1000));
    let dial: Box<dyn FnMut() -> rans_sc::error::Result<FaultyTransport> + Send> = {
        let pair_seed = Arc::clone(&pair_seed);
        Box::new(move || {
            let s = pair_seed.fetch_add(1, Ordering::Relaxed);
            // Drops only: a lost frame forces the retry/resync paths to
            // compose, without duplicate stale skew replies muddying
            // the once-per-call resync accounting.
            let spec = FaultSpec::drops(0.15);
            let (client, server) = FaultyTransport::pair(s, spec, spec);
            hand_tx
                .send(server)
                .map_err(|_| Error::transport("responder spawner gone"))?;
            Ok(client)
        })
    };
    let cfg = SessionConfig {
        deadline_ms: 4_000,
        try_timeout_ms: 60,
        max_retries: 20,
        base_backoff_ms: 1,
        max_backoff_ms: 8,
        heartbeat_ms: 0,
        seed: 17,
    };
    let mut dial = dial;
    let first = dial().expect("initial dial cannot fail");
    let resyncs = Arc::new(AtomicU64::new(0));
    let hook_resyncs = Arc::clone(&resyncs);
    let mut session = Session::new(first, cfg)
        .with_metrics(Arc::clone(&registry))
        .with_connector(dial)
        .with_model_version(1)
        .with_resync(Box::new(move |active_version| {
            // Stands in for a registry re-fetch of the active version.
            hook_resyncs.fetch_add(1, Ordering::Relaxed);
            Ok(active_version)
        }));

    let n = 120usize;
    let mut ok = 0usize;
    for i in 0..n {
        if i == n / 3 {
            active.store(2, Ordering::Relaxed); // first hot-swap lands
        }
        if i == 2 * n / 3 {
            active.store(3, Ordering::Relaxed); // and a second one
        }
        let payload: Vec<u8> = (0..1 + (i % 53)).map(|j| ((i * 13 + j) & 0x7F) as u8).collect();
        match session.call(FrameKind::InferLm { model: "soak".into(), payload: payload.clone() })
        {
            Ok(reply) => match reply.kind {
                FrameKind::Logits { ref data, .. } => {
                    assert_eq!(data.len(), 1, "req {i}");
                    assert_eq!(data[0], checksum(&payload), "req {i}: reply integrity");
                    ok += 1;
                }
                ref other => panic!("req {i}: unexpected reply kind {other:?}"),
            },
            Err(e) => {
                assert!(e.is_retryable(), "req {i}: non-retryable error escaped: {e}");
            }
        }
    }
    assert!(ok >= n * 2 / 3, "too few successes across the flips: {ok}/{n}");
    assert!(registry.get("session.skew_total") >= 2, "both flips must surface as skew");
    assert!(registry.get("session.resync_total") >= 2, "both flips must resync");
    assert_eq!(
        registry.get("session.resync_total"),
        resyncs.load(Ordering::Relaxed),
        "every counted resync came from the hook"
    );
    assert_eq!(session.model_version(), Some(3), "session ends on the final deployment");
    drop(session); // hangs up: responders and the spawner drain out
    spawner.join().unwrap();
    done.store(true, Ordering::Relaxed);
}

/// The daemon fleet fault family: every chaos schedule from
/// [`fault_families`] is run as a whole synthetic fleet — dozens of
/// concurrent chaos-linked edge sessions against one actor daemon —
/// and the daemon's no-silent-drop contract is asserted per family:
/// zero hangs (watchdog + per-family wall ceiling), every request ends
/// in exactly one explicit outcome, and most land despite the faults.
#[test]
fn daemon_fleet_soak_every_outcome_is_explicit() {
    use rans_sc::coordinator::loadgen::{self, LoadgenConfig};

    let done = Arc::new(AtomicBool::new(false));
    arm_watchdog(480, Arc::clone(&done));

    let only_family = std::env::var("RANS_SC_CHAOS_FAULT").ok();
    let families: Vec<_> = fault_families()
        .into_iter()
        .filter(|(name, _)| only_family.as_deref().map(|f| f == *name).unwrap_or(true))
        .collect();
    assert!(
        !families.is_empty(),
        "RANS_SC_CHAOS_FAULT={only_family:?} matches no fault family"
    );

    for &(name, spec) in &families {
        let cfg = LoadgenConfig {
            edges: 48,
            requests_per_edge: 4,
            tenants: 6,
            seed: 0xDAE0 ^ name.len() as u64,
            faulty_share: 1.0,
            chaos: spec,
            session: SessionConfig {
                deadline_ms: 8_000,
                try_timeout_ms: 100,
                max_retries: 10,
                base_backoff_ms: 1,
                max_backoff_ms: 8,
                heartbeat_ms: 0,
                seed: 0xDAE0,
            },
            ..LoadgenConfig::default()
        };
        let started = Instant::now();
        let report = loadgen::run(&cfg);
        let elapsed = started.elapsed();
        println!(
            "daemon soak '{name}': {} ok / {} rejected / {} failed over {} req ({elapsed:?})",
            report.ok, report.rejected, report.failed, report.requests
        );
        assert_eq!(
            report.unanswered, 0,
            "'{name}': a request ended with no explicit outcome"
        );
        assert_eq!(
            report.ok + report.rejected + report.failed,
            report.requests,
            "'{name}': outcome accounting must close"
        );
        assert!(report.ok > 0, "'{name}': retrying sessions should land requests");
        assert!(
            elapsed < Duration::from_secs(120),
            "'{name}': fleet of {} took {elapsed:?} — treating as a hang",
            cfg.edges
        );
    }
    done.store(true, Ordering::Relaxed);
}

/// A deliberately noisy tenant — eight chaos-linked connections
/// hammering concurrently against a two-slot per-tenant quota — must be
/// shed on its own budget while a quiet tenant's sequential requests
/// all succeed. The starvation check is end-to-end: the quiet tenant
/// runs *during* the noise, over the same daemon.
#[test]
fn daemon_noisy_tenant_cannot_starve_quiet_tenants() {
    use rans_sc::coordinator::loadgen::synthetic_exec;
    use rans_sc::coordinator::{Daemon, DaemonConfig};

    let done = Arc::new(AtomicBool::new(false));
    arm_watchdog(240, Arc::clone(&done));

    let daemon = Daemon::new(
        DaemonConfig { tenant_quota: 2, max_inflight: 64, ..DaemonConfig::default() },
        synthetic_exec(2_000), // 2 ms service keeps the noisy tenant saturated
    );

    let noisy_conns = 8usize;
    let per_conn = 25usize;
    let mut noisy_ends = Vec::new();
    for i in 0..noisy_conns {
        let spec = FaultSpec::chaos(0.05, Duration::from_micros(300));
        let (edge, cloud) = FaultyTransport::pair(0xBAD0 + i as u64, spec, spec);
        daemon.attach(Box::new(cloud), "noisy");
        noisy_ends.push(edge);
    }
    let (quiet_edge, quiet_cloud) =
        FaultyTransport::pair(7, FaultSpec::none(), FaultSpec::none());
    daemon.attach(Box::new(quiet_cloud), "quiet");

    let quiet_ok = thread::scope(|s| {
        for (i, edge) in noisy_ends.into_iter().enumerate() {
            s.spawn(move || {
                let mut session = Session::new(
                    edge,
                    SessionConfig {
                        deadline_ms: 2_000,
                        try_timeout_ms: 100,
                        max_retries: 1,
                        base_backoff_ms: 1,
                        max_backoff_ms: 2,
                        heartbeat_ms: 0,
                        seed: i as u64,
                    },
                );
                for r in 0..per_conn {
                    let payload = vec![(i * 16 + r) as u8; 24];
                    // Outcomes here don't matter (mostly quota sheds);
                    // what matters is the sustained pressure.
                    let _ =
                        session.call(FrameKind::InferLm { model: "noisy".into(), payload });
                }
            });
        }
        // The quiet tenant's whole run happens while the noise is live.
        let mut session = Session::new(
            quiet_edge,
            SessionConfig {
                deadline_ms: 4_000,
                try_timeout_ms: 500,
                max_retries: 3,
                base_backoff_ms: 2,
                max_backoff_ms: 20,
                heartbeat_ms: 0,
                seed: 99,
            },
        );
        let mut ok = 0usize;
        for r in 0..40usize {
            let payload = vec![r as u8; 24];
            match session.call(FrameKind::InferLm { model: "quiet".into(), payload }) {
                Ok(frame) => match frame.kind {
                    FrameKind::Logits { .. } => ok += 1,
                    ref other => panic!("quiet req {r}: unexpected reply {other:?}"),
                },
                Err(e) => panic!("quiet req {r}: explicit failure under noise: {e}"),
            }
        }
        ok
    });

    assert_eq!(quiet_ok, 40, "quiet tenant must not be starved by the noisy one");
    let metrics = daemon.metrics();
    assert!(
        metrics.get("tenant.noisy.quota_rejected") > 0,
        "noisy tenant never hit its quota: {}",
        metrics.snapshot_json()
    );
    assert_eq!(
        metrics.get("tenant.quiet.quota_rejected"),
        0,
        "quota sheds must stay on the tenant that caused them"
    );
    daemon.shutdown();
    done.store(true, Ordering::Relaxed);
}

/// A permanently overloaded peer: the session must surface the shed as
/// an explicit `Rejected` carrying the server's retry-after hint, and
/// the shed must be visible in the metrics snapshot.
#[test]
fn overload_shed_surfaces_as_explicit_rejected() {
    let done = Arc::new(AtomicBool::new(false));
    arm_watchdog(120, Arc::clone(&done));

    let (client, mut server) = FaultyTransport::pair(42, FaultSpec::none(), FaultSpec::none());
    let srv = thread::spawn(move || loop {
        let frame = match server.recv() {
            Ok(f) => f,
            Err(_) => return,
        };
        let busy = FrameKind::Busy { retry_after_ms: 5, message: "always full".into() };
        if server.send(&Frame::new(frame.request_id, busy)).is_err() {
            return;
        }
    });
    let registry = Arc::new(Registry::new());
    let cfg = SessionConfig {
        deadline_ms: 2_000,
        try_timeout_ms: 200,
        max_retries: 3,
        base_backoff_ms: 1,
        max_backoff_ms: 4,
        heartbeat_ms: 0,
        seed: 7,
    };
    let mut session = Session::new(client, cfg).with_metrics(Arc::clone(&registry));
    let err = session.call(FrameKind::Ping).unwrap_err();
    match err {
        Error::Rejected { retry_after_ms, .. } => assert_eq!(retry_after_ms, 5),
        other => panic!("expected Rejected, got {other}"),
    }
    assert_eq!(registry.get("session.shed_total"), 4, "initial attempt + 3 retries");
    assert!(registry.snapshot_json().contains("session.shed_total"));
    drop(session);
    srv.join().unwrap();
    done.store(true, Ordering::Relaxed);
}
