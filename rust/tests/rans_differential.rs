//! Differential fuzz wall for the SIMD gather decoder.
//!
//! `rust/src/rans/simd.rs` promises that every backend behind the
//! cross-ISA seam — SSE4.1 (4-state) and AVX2 (8-state) on x86_64,
//! NEON (both widths) on aarch64 — is *symbol-identical* to the
//! const-generic scalar loop, on valid streams and on corrupt ones.
//! This suite pins that promise from outside the crate:
//!
//! * seeded-LCG tensors swept over states × lanes × Q × tail counts
//!   (count < N, count = 0, single-symbol alphabets), decoded through
//!   the scalar backend, the auto dispatcher, and every force-selected
//!   SIMD backend the host offers;
//! * encoder byte-identity against the committed golden vectors (the
//!   same `raw_ms*.hex` files the Python oracle generated), so the
//!   streams being differentially decoded are pinned to the wire
//!   format, not merely self-consistent;
//! * a mutation fuzzer that flips and truncates bytes of valid v1/v2
//!   streams and asserts decode never panics, that no backend ever
//!   returns the original symbols for mutated bytes (encode/decode are
//!   inverse bijections, so `Ok(original)` would imply the bytes were
//!   unchanged), and that all backends agree on acceptance and output;
//! * a dispatch-seam check so this suite can never silently compare
//!   scalar against scalar on a SIMD-capable builder.

use rans_sc::rans::simd::{self, Backend};
use rans_sc::rans::{
    decode_interleaved, decode_multistate, decode_multistate_scalar,
    encode_interleaved_with_layout, encode_multistate, FreqTable, StreamLayout,
};
use rans_sc::testutil;

/// Seeded-LCG symbol tensor — the same generator family the golden
/// vectors use (`gen_golden.py`), skewed ~50% toward symbol 0.
fn lcg_symbols(seed: u64, len: usize, alphabet: usize) -> Vec<u32> {
    let mut lcg = seed;
    (0..len)
        .map(|_| {
            lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            if (lcg >> 20) & 1 == 0 {
                0
            } else {
                ((lcg >> 33) % alphabet as u64) as u32
            }
        })
        .collect()
}

/// The SIMD backends covering `states` that are runnable on this host.
fn simd_backends(states: usize) -> Vec<Backend> {
    [Backend::Sse41, Backend::Avx2, Backend::Neon]
        .into_iter()
        .filter(|b| b.supports(states) && simd::backend_available(*b))
        .collect()
}

/// Decode `bytes` through every backend (scalar + available SIMD +
/// auto), assert they all agree, and return the scalar result.
fn decode_all_backends(
    bytes: &[u8],
    count: usize,
    table: &FreqTable,
    states: usize,
    ctx: &str,
) -> Result<Vec<u32>, ()> {
    let scalar = decode_multistate_scalar(bytes, count, table, states);
    let auto = decode_multistate(bytes, count, table, states);
    assert_eq!(scalar.is_ok(), auto.is_ok(), "{ctx}: scalar vs auto acceptance");
    if let (Ok(a), Ok(b)) = (&scalar, &auto) {
        assert_eq!(a, b, "{ctx}: scalar vs auto symbols");
    }
    for backend in simd_backends(states) {
        let forced = simd::decode_multistate_with(bytes, count, table, states, backend);
        assert_eq!(
            scalar.is_ok(),
            forced.is_ok(),
            "{ctx}: scalar vs {} acceptance",
            backend.name()
        );
        if let (Ok(a), Ok(b)) = (&scalar, &forced) {
            assert_eq!(a, b, "{ctx}: scalar vs {} symbols", backend.name());
        }
    }
    scalar.map_err(|_| ())
}

/// The core sweep: states × Q × tail counts, including count = 0,
/// count < N, and counts straddling the SIMD loop's byte-budget exit.
#[test]
fn simd_and_scalar_decode_identical_across_sweep() {
    for q in [2u32, 4, 8] {
        let alphabet = 1usize << q;
        for states in [4usize, 8] {
            let counts = [
                0usize,
                1,
                states - 1,
                states,
                states + 1,
                2 * states + 3,
                997,
                40_003,
            ];
            for count in counts {
                let seed = 0xD1FF ^ ((q as u64) << 32) ^ ((states as u64) << 16) ^ count as u64;
                let symbols = lcg_symbols(seed, count, alphabet);
                let table = FreqTable::from_symbols(&symbols, alphabet);
                let bytes = encode_multistate(&symbols, &table, states).unwrap();
                let ctx = format!("q={q} states={states} count={count}");
                let decoded = decode_all_backends(&bytes, count, &table, states, &ctx)
                    .expect("valid stream must decode");
                assert_eq!(decoded, symbols, "{ctx}");
            }
        }
    }
}

/// Degenerate tables: a single-symbol alphabet (freq == SCALE, decode
/// never renormalizes — all-SIMD rounds with an empty refill mask) and
/// an alphabet with a never-seen symbol.
#[test]
fn single_symbol_alphabets_decode_identically() {
    for states in [4usize, 8] {
        for count in [0usize, 1, 7, 8, 9, 5000] {
            let symbols = vec![0u32; count];
            // Alphabet 1: the only symbol owns the whole slot space.
            let table = FreqTable::from_symbols(&symbols, 1);
            let bytes = encode_multistate(&symbols, &table, states).unwrap();
            let ctx = format!("alphabet=1 states={states} count={count}");
            let decoded = decode_all_backends(&bytes, count, &table, states, &ctx)
                .expect("valid stream must decode");
            assert_eq!(decoded, symbols, "{ctx}");
            // Alphabet 2 with symbol 1 never occurring.
            let table2 = FreqTable::from_symbols(&symbols, 2);
            let bytes2 = encode_multistate(&symbols, &table2, states).unwrap();
            let ctx2 = format!("alphabet=2 states={states} count={count}");
            let decoded2 = decode_all_backends(&bytes2, count, &table2, states, &ctx2)
                .expect("valid stream must decode");
            assert_eq!(decoded2, symbols, "{ctx2}");
        }
    }
}

/// Full lanes × states sweep through the self-describing stream layout
/// layer — the route the engine's per-lane decode jobs take, so the
/// SIMD dispatch is exercised behind real v1/v2 framing.
#[test]
fn lanes_by_states_sweep_through_layout_layer() {
    for states in [1usize, 2, 4, 8] {
        for lanes in [1usize, 2, 3, 8] {
            for count in [0usize, 3, 17, 10_000] {
                let symbols = lcg_symbols(0xA5 ^ count as u64, count, 64);
                let table = FreqTable::from_symbols(&symbols, 64);
                let layout = if states == 1 {
                    StreamLayout::V1
                } else {
                    StreamLayout::MultiState(states)
                };
                let bytes =
                    encode_interleaved_with_layout(&symbols, &table, lanes, layout, false)
                        .unwrap();
                for parallel in [false, true] {
                    let back = decode_interleaved(&bytes, &table, parallel).unwrap();
                    assert_eq!(back, symbols, "states={states} lanes={lanes} count={count}");
                }
            }
        }
    }
}

/// The anti-scalar-vs-scalar guard: on a SIMD-capable builder the auto
/// dispatcher must select the SIMD backend, so the differential
/// assertions above genuinely compared two implementations. (On hosts
/// without the features the forced paths error loudly instead —
/// checked in `rans::simd`'s unit tests.)
#[test]
fn dispatch_selects_simd_on_capable_hosts() {
    // A RANS_SC_FORCE_BACKEND override rewires dispatch by design (the
    // aarch64 CI leg pins neon this way): assert the forced semantics
    // and skip the auto-dispatch pins below.
    let forced = simd::forced_backend().expect("force override must name a usable backend");
    if let Some(forced) = forced {
        for n in [1usize, 2, 4, 8] {
            let expect = if forced.supports(n) { forced } else { Backend::Scalar };
            assert_eq!(simd::backend_for(n).unwrap(), expect, "forced, n={n}");
        }
        return;
    }
    // The anti-scalar-vs-scalar property itself, ISA-independently:
    // wherever some SIMD backend can run, auto dispatch picks one.
    for n in [4usize, 8] {
        let picked = simd::backend_for(n).unwrap();
        let runnable = simd_backends(n);
        if runnable.is_empty() {
            assert_eq!(picked, Backend::Scalar, "n={n}");
        } else {
            assert!(runnable.contains(&picked), "n={n} picked {}", picked.name());
        }
    }
    // Arch-specific pins so a capable CI builder can't silently regress
    // to the scalar fallback.
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("sse4.1") {
            assert_eq!(simd::backend_for(4).unwrap(), Backend::Sse41);
            assert_eq!(simd_backends(4), vec![Backend::Sse41]);
        }
        if is_x86_feature_detected!("avx2") {
            assert_eq!(simd::backend_for(8).unwrap(), Backend::Avx2);
            assert_eq!(simd_backends(8), vec![Backend::Avx2]);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is baseline on aarch64 — both SIMD widths must dispatch
        // to it unconditionally.
        assert_eq!(simd::backend_for(4).unwrap(), Backend::Neon);
        assert_eq!(simd::backend_for(8).unwrap(), Backend::Neon);
        assert_eq!(simd_backends(4), vec![Backend::Neon]);
        assert_eq!(simd_backends(8), vec![Backend::Neon]);
    }
    // Scalar-only widths never dispatch to SIMD anywhere.
    assert_eq!(simd::backend_for(1).unwrap(), Backend::Scalar);
    assert_eq!(simd::backend_for(2).unwrap(), Backend::Scalar);
}

/// Encoder byte-identity against the committed golden vectors (the
/// Python oracle's output) — the streams the differential decode sweep
/// runs on are thereby pinned to the wire format itself.
#[test]
fn encode_matches_committed_golden_vectors() {
    // The golden tensor replica from gen_golden.py / golden_vectors.rs.
    let alphabet = 1usize << 4;
    let mut lcg: u64 = 0xC0FFEE + 4;
    let symbols: Vec<u32> = (0..4096)
        .map(|_| {
            lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            if ((lcg >> 29) & 7) < 5 {
                1 // background / zero point
            } else {
                ((lcg >> 33) % alphabet as u64) as u32
            }
        })
        .collect();
    let table = FreqTable::from_symbols(&symbols, alphabet);
    let goldens: [(usize, &str); 3] = [
        (2, include_str!("golden/raw_ms2_q4.hex")),
        (4, include_str!("golden/raw_ms4_q4.hex")),
        (8, include_str!("golden/raw_ms8_q4.hex")),
    ];
    for (states, hex) in goldens {
        let hex = hex.trim();
        let golden: Vec<u8> = (0..hex.len() / 2)
            .map(|i| u8::from_str_radix(&hex[2 * i..2 * i + 2], 16).expect("bad golden hex"))
            .collect();
        let encoded = encode_multistate(&symbols, &table, states).unwrap();
        assert_eq!(encoded, golden, "encoder drifted from golden vector (states={states})");
        let ctx = format!("golden states={states}");
        let decoded = decode_all_backends(&golden, symbols.len(), &table, states, &ctx)
            .expect("golden stream must decode");
        assert_eq!(decoded, symbols, "{ctx}");
    }
}

/// Mutation fuzzer (protocol_fuzz's pattern grown to the rans layer):
/// flip bytes of valid multi-state streams. Decode must never panic;
/// no backend may return the *original* symbols for mutated bytes
/// (encode/decode are inverse bijections — `Ok(original)` with every
/// end-of-stream check passing would imply the bytes were unchanged);
/// and all backends must agree on acceptance and output.
#[test]
fn mutation_fuzz_bitflips() {
    testutil::check(
        "bitflipped multi-state streams",
        150,
        |rng| {
            let states = *rng.choose(&[4usize, 8]);
            let alphabet = *rng.choose(&[2usize, 16, 256]);
            let len = 16 + rng.below_usize(3000);
            let symbols = lcg_symbols(rng.next_u64(), len, alphabet);
            let table = FreqTable::from_symbols(&symbols, alphabet);
            let mut bytes = encode_multistate(&symbols, &table, states).unwrap();
            for _ in 0..1 + rng.below_usize(3) {
                let i = rng.below_usize(bytes.len());
                bytes[i] ^= 1 << rng.below(8);
            }
            (states, symbols, table, bytes)
        },
        |(states, symbols, table, bytes)| {
            match decode_all_backends(bytes, symbols.len(), table, *states, "bitflip fuzz") {
                Err(()) => true,
                // A mutated stream may still decode, but never to the
                // original symbols (see the bijection argument above).
                Ok(decoded) => decoded != *symbols,
            }
        },
    );
}

/// Mutation fuzzer, truncation arm: cutting a valid stream anywhere
/// must never panic and never reproduce the original symbols; cutting
/// into the state-word block must be a hard error on every backend.
#[test]
fn mutation_fuzz_truncations() {
    testutil::check(
        "truncated multi-state streams",
        150,
        |rng| {
            let states = *rng.choose(&[4usize, 8]);
            let len = 16 + rng.below_usize(2000);
            let symbols = lcg_symbols(rng.next_u64(), len, 40.min(len));
            let table = FreqTable::from_symbols(&symbols, 40.min(len));
            let bytes = encode_multistate(&symbols, &table, states).unwrap();
            let cut = rng.below_usize(bytes.len());
            (states, symbols, table, bytes, cut)
        },
        |(states, symbols, table, bytes, cut)| {
            let truncated = &bytes[..*cut];
            let outcome =
                decode_all_backends(truncated, symbols.len(), table, *states, "truncation fuzz");
            if *cut < 4 * states {
                // Shorter than the state-word block: every backend must
                // reject outright.
                outcome.is_err()
            } else {
                match outcome {
                    Err(()) => true,
                    Ok(decoded) => decoded != *symbols,
                }
            }
        },
    );
}

/// The same mutation wall for v1 (scalar) streams through the layout
/// layer: framing bytes, state words, and renorm bytes all get hit.
#[test]
fn mutation_fuzz_framed_streams() {
    testutil::check(
        "bitflipped framed v1/v2 streams",
        100,
        |rng| {
            let states = *rng.choose(&[1usize, 2, 4, 8]);
            let lanes = 1 + rng.below_usize(8);
            let len = rng.below_usize(4000);
            let symbols = lcg_symbols(rng.next_u64(), len, 64);
            let table = FreqTable::from_symbols(&symbols, 64);
            let layout = if states == 1 {
                StreamLayout::V1
            } else {
                StreamLayout::MultiState(states)
            };
            let mut bytes =
                encode_interleaved_with_layout(&symbols, &table, lanes, layout, false).unwrap();
            let i = rng.below_usize(bytes.len());
            bytes[i] ^= 1 << rng.below(8);
            (symbols, table, bytes)
        },
        |(symbols, table, bytes)| {
            // Must return (not panic); a mutated framed stream may parse
            // and decode, but only ever to different symbols — the
            // framing re-derives per-lane counts, and each lane decode
            // is the bijection argued above.
            match decode_interleaved(bytes, table, false) {
                Err(_) => true,
                Ok(decoded) => decoded != *symbols,
            }
        },
    );
}
