//! Artifact-gated integration tests: exercise the real PJRT runtime,
//! the edge↔cloud loop, and both model families.
//!
//! Skipped (with a message) when `artifacts/manifest.json` is absent —
//! run `make artifacts` first. Set `RANS_SC_ARTIFACTS` to point at a
//! different artifact tree.

use std::sync::Arc;

use rans_sc::coordinator::{CloudNode, EdgeConfig, EdgeNode, InProcTransport, LmEdgeNode, Transport};
use rans_sc::data::{lm_tasks::score_choices, McTask, VisionSet};
use rans_sc::pipeline::{self, PipelineConfig};
use rans_sc::runtime::{Engine, ExecPool, LmSplitExec, Manifest, VisionSplitExec};

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {dir} (run `make artifacts`)");
        return None;
    }
    // Absent artifacts are an expected skip; a manifest that is present
    // but unreadable is a broken build and must fail loudly instead of
    // silently skipping the whole suite.
    if let Err(e) = Manifest::load(&dir) {
        panic!("artifacts present at {dir} but the manifest is unusable: {e}");
    }
    Some(dir)
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[test]
fn vision_head_tail_roundtrip_matches_raw_path() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Arc::new(Engine::cpu().unwrap());
    let pool = ExecPool::new(engine, dir.as_str());
    let entry = &manifest.vision[0];
    let split = &entry.splits[0];
    let exec =
        VisionSplitExec::load(&pool, &manifest, &entry.name, split.sl, split.batch).unwrap();
    let set = VisionSet::load(manifest.resolve(&entry.test_data)).unwrap();
    let (xs, _) = set.batch(0, split.batch);

    // Raw path.
    let feat = exec.run_head_raw(&xs).unwrap();
    assert_eq!(feat.len(), split.feature_len);
    let logits_raw = exec.run_tail_raw(&feat).unwrap();
    assert_eq!(logits_raw.len(), split.batch * entry.num_classes);

    // Quantized path at a generous Q: predictions should agree with raw.
    let (symbols, params) = exec.run_head(&xs, 8).unwrap();
    assert_eq!(symbols.len(), split.feature_len);
    let (container, _) =
        pipeline::compress_quantized(&symbols, params, &PipelineConfig::paper(8)).unwrap();
    let (dec_syms, dec_params) = pipeline::decompress_to_symbols(&container).unwrap();
    assert_eq!(dec_syms, symbols);
    let logits_q = exec.run_tail(&dec_syms, &dec_params).unwrap();
    assert_eq!(logits_q.len(), logits_raw.len());
    for b in 0..split.batch {
        let r = argmax(&logits_raw[b * entry.num_classes..(b + 1) * entry.num_classes]);
        let q = argmax(&logits_q[b * entry.num_classes..(b + 1) * entry.num_classes]);
        assert_eq!(r, q, "Q=8 prediction diverged from raw at sample {b}");
    }
}

#[test]
fn head_symbols_respect_q_alphabet() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Arc::new(Engine::cpu().unwrap());
    let pool = ExecPool::new(engine, dir.as_str());
    let entry = &manifest.vision[0];
    let split = &entry.splits[0];
    let exec =
        VisionSplitExec::load(&pool, &manifest, &entry.name, split.sl, split.batch).unwrap();
    let set = VisionSet::load(manifest.resolve(&entry.test_data)).unwrap();
    let (xs, _) = set.batch(1, split.batch);
    for q in [2u8, 3, 4, 6, 8] {
        let (symbols, params) = exec.run_head(&xs, q).unwrap();
        let max = (1u16 << q) - 1;
        assert!(symbols.iter().all(|&s| s <= max), "Q={q}");
        assert_eq!(params.q, q);
        assert!(params.scale > 0.0);
    }
}

#[test]
fn edge_cloud_inproc_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let cloud = Arc::new(CloudNode::new(&dir).unwrap());
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.vision[0].clone();
    let split = entry.splits[0].clone();

    let (edge_end, mut cloud_end) = InProcTransport::pair();
    let server = {
        let cloud = Arc::clone(&cloud);
        std::thread::spawn(move || cloud.serve_transport(&mut cloud_end as &mut dyn Transport))
    };

    let engine = Arc::new(Engine::cpu().unwrap());
    let pool = ExecPool::new(engine, dir.as_str());
    let exec = Arc::new(
        VisionSplitExec::load(&pool, &manifest, &entry.name, split.sl, split.batch).unwrap(),
    );
    let set = VisionSet::load(manifest.resolve(&entry.test_data)).unwrap();
    let edge = EdgeNode::new(
        Arc::clone(&exec),
        edge_end,
        EdgeConfig::paper(&entry.name, split.sl, split.batch, 4),
    );
    edge.ping().unwrap();
    let (xs, _) = set.batch(0, split.batch);
    let out = edge.infer(&xs).unwrap();
    assert_eq!(out.logits.len(), split.batch * entry.num_classes);
    assert!(out.payload_bytes > 0);
    assert!(out.payload_bytes < split.feature_len * 4, "must beat raw f32");
    assert!(out.breakdown.transfer_ms > 0.0);
    let raw = edge.infer_raw(&xs).unwrap();
    assert!(out.payload_bytes < raw.payload_bytes / 2, "≥2x reduction expected");
    // Plan cache: second request reuses the plan.
    let _ = edge.infer(&xs).unwrap();
    let (hits, misses) = edge.plan_cache_stats();
    assert_eq!(misses, 1);
    assert!(hits >= 1);
    drop(edge);
    server.join().unwrap().unwrap();
}

#[test]
fn cloud_rejects_corrupt_container_gracefully() {
    let Some(dir) = artifacts_dir() else { return };
    use rans_sc::coordinator::{Frame, FrameKind};
    let cloud = CloudNode::new(&dir).unwrap();
    let manifest = cloud.manifest().clone();
    let entry = &manifest.vision[0];
    let split = &entry.splits[0];
    let frame = Frame::new(
        5,
        FrameKind::InferVision {
            model: entry.name.clone(),
            sl: split.sl,
            batch: split.batch,
            payload: vec![0xAB; 256],
        },
    );
    let reply = cloud.handle(&frame);
    assert_eq!(reply.request_id, 5);
    assert!(matches!(reply.kind, FrameKind::ServerError { .. }));
    // Unknown model is also a clean error.
    let frame = Frame::new(
        6,
        FrameKind::InferVision {
            model: "not_a_model".into(),
            sl: 1,
            batch: 1,
            payload: vec![],
        },
    );
    assert!(matches!(cloud.handle(&frame).kind, FrameKind::ServerError { .. }));
}

/// The Llama2-style half-precision path over real artifacts: hidden
/// states narrowed to bf16 on the edge, shipped through
/// `LmEdgeNode::infer_features` (fused conversion-on-load quantize, no
/// intermediate f32 Vec), decoded and consumed by the cloud node.
#[test]
fn lm_bf16_features_end_to_end() {
    use rans_sc::tensor::{half, Dtype, TensorRef};

    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    if manifest.lm.is_empty() {
        eprintln!("skipping: no LM artifacts");
        return;
    }
    let cloud = Arc::new(CloudNode::new(&dir).unwrap());
    let (edge_end, mut cloud_end) = InProcTransport::pair();
    let server = {
        let cloud = Arc::clone(&cloud);
        std::thread::spawn(move || cloud.serve_transport(&mut cloud_end as &mut dyn Transport))
    };
    let engine = Arc::new(Engine::cpu().unwrap());
    let pool = ExecPool::new(engine, dir.as_str());
    let lm_name = manifest.lm[0].name.clone();
    let exec = Arc::new(LmSplitExec::load(&pool, &manifest, &lm_name).unwrap());
    let lm = exec.entry.clone();
    let task = McTask::load(manifest.resolve(&lm.tasks[0].path)).unwrap();
    let edge = LmEdgeNode::new(
        Arc::clone(&exec),
        edge_end,
        EdgeConfig::paper(&lm_name, lm.split, lm.batch, 6).with_dtype(Dtype::Bf16),
    );
    let item = &task.items[0];
    let hidden = exec.run_head_raw(&task.item_batch(item)).unwrap();
    let bf16: Vec<u16> = hidden.iter().map(|&x| half::f32_to_bf16(x)).collect();
    // Wrong dtype is rejected against the edge config…
    assert!(edge.infer_features(TensorRef::from_f32(&hidden)).is_err());
    // …the configured bf16 path goes end to end.
    let out = edge.infer_features(TensorRef::from_bf16_bits(&bf16)).unwrap();
    assert_eq!(out.logits.len(), lm.batch * lm.seq_len * lm.vocab);
    assert!(out.payload_bytes < bf16.len() * 2, "must beat raw bf16");
    // The raw bf16 baseline halves the f32 baseline's wire bytes.
    let raw = edge.infer_raw_features(TensorRef::from_bf16_bits(&bf16)).unwrap();
    assert_eq!(raw.payload_bytes, bf16.len() * 2);
    drop(edge);
    server.join().unwrap().unwrap();
}

#[test]
fn lm_split_end_to_end_scores_items() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    if manifest.lm.is_empty() {
        eprintln!("skipping: no LM artifacts");
        return;
    }
    let cloud = Arc::new(CloudNode::new(&dir).unwrap());
    let (edge_end, mut cloud_end) = InProcTransport::pair();
    let server = {
        let cloud = Arc::clone(&cloud);
        std::thread::spawn(move || cloud.serve_transport(&mut cloud_end as &mut dyn Transport))
    };
    let engine = Arc::new(Engine::cpu().unwrap());
    let pool = ExecPool::new(engine, dir.as_str());
    let lm_name = manifest.lm[0].name.clone();
    let exec = Arc::new(LmSplitExec::load(&pool, &manifest, &lm_name).unwrap());
    let lm = exec.entry.clone();
    let task = McTask::load(manifest.resolve(&lm.tasks[0].path)).unwrap();
    let edge = LmEdgeNode::new(
        Arc::clone(&exec),
        edge_end,
        EdgeConfig::paper(&lm_name, lm.split, lm.batch, 6),
    );
    let item = &task.items[0];
    let out = edge.infer(&task.item_batch(item)).unwrap();
    assert_eq!(out.logits.len(), lm.batch * lm.seq_len * lm.vocab);
    let pick = score_choices(&out.logits, &task, item);
    assert!(pick < task.n_choices);
    assert!(out.payload_bytes < lm.hidden_len * 4);
    drop(edge);
    server.join().unwrap().unwrap();
}
