#!/usr/bin/env python3
"""Reference implementation + golden-vector generator for the rANS pipeline.

This script is the cross-language oracle for the division-free rANS core:

1. It validates the reciprocal-multiply exact-division scheme used by
   `rust/src/rans/symbol.rs` (q = (x + mulhi32(x, rcp_lo)) >> shift with
   m = 2^32 + rcp_lo = ceil(2^(32+shift) / freq)) against hardware
   division for every normalized frequency 1..=4096 at adversarial
   states.
2. It re-implements the v1/v2 container pipeline (ModCsr, frequency
   normalization, scalar rANS, lane framing, CRC-32) bit-for-bit and
   emits the committed golden vectors under rust/tests/golden/ that
   `rust/tests/golden_vectors.rs` asserts byte-identity against.

Run from the repo root:  python3 rust/tests/golden/gen_golden.py

Pure stdlib; deterministic; regenerating must reproduce the committed
files exactly (the script fails loudly if its own encode/decode
roundtrip breaks).
"""

import math
import os
import struct
import sys
import zlib

SCALE_BITS = 12
SCALE = 1 << SCALE_BITS
STATE_LOWER = 1 << 16
MASK32 = 0xFFFFFFFF

OUT_DIR = os.path.dirname(os.path.abspath(__file__))

# --------------------------------------------------------------- varint


def write_varint(buf: bytearray, value: int) -> None:
    assert value >= 0
    while True:
        byte = value & 0x7F
        value >>= 7
        if value == 0:
            buf.append(byte)
            return
        buf.append(byte | 0x80)


def write_zigzag(buf: bytearray, value: int) -> None:
    write_varint(buf, ((value << 1) ^ (value >> 63)) & 0xFFFFFFFFFFFFFFFF)


# ------------------------------------------------- frequency normalization


def from_counts(counts):
    """Exact replica of FreqTable::from_counts largest-remainder logic."""
    m = len(counts)
    total = sum(counts)
    assert 0 < m <= SCALE and total > 0
    freq = [0] * m
    assigned = 0
    remainders = []
    for i, c in enumerate(counts):
        if c == 0:
            continue
        exact = c * SCALE / total  # f64 in Rust; Python float is the same
        floor = max(int(math.floor(exact)), 1)
        freq[i] = floor
        assigned += floor
        remainders.append((exact - math.floor(exact), i))
    if assigned < SCALE:
        need = SCALE - assigned
        remainders.sort(key=lambda t: (-t[0], t[1]))  # stable, like sort_by
        idx = 0
        while need > 0:
            _, i = remainders[idx % len(remainders)]
            freq[i] += 1
            need -= 1
            idx += 1
    elif assigned > SCALE:
        excess = assigned - SCALE
        order = [i for i in range(m) if freq[i] > 1]
        order.sort(key=lambda i: -freq[i])  # stable desc, ties by index
        idx = 0
        while excess > 0:
            assert order, "cannot normalize"
            i = order[idx % len(order)]
            if freq[i] > 1:
                freq[i] -= 1
                excess -= 1
            idx += 1
            if idx % len(order) == 0:
                order = [j for j in order if freq[j] > 1]
    assert sum(freq) == SCALE
    return freq


def cdf_of(freq):
    cdf = [0] * (len(freq) + 1)
    for i, f in enumerate(freq):
        cdf[i + 1] = cdf[i] + f
    return cdf


# ----------------------------------------------------------- scalar rANS


def rans_encode_div(symbols, freq, cdf):
    """The pre-optimization encoder: hardware div + mod per symbol."""
    state = STATE_LOWER
    rev = bytearray()
    for sym in reversed(symbols):
        f = freq[sym]
        assert f > 0
        x_max = ((STATE_LOWER >> SCALE_BITS) << 16) * f
        while state >= x_max:
            rev.append((state >> 8) & 0xFF)
            rev.append(state & 0xFF)
            state >>= 16
        state = ((state // f) << SCALE_BITS) + (state % f) + cdf[sym]
        assert state <= MASK32
    out = bytearray(struct.pack("<I", state))
    out.extend(reversed(rev))
    return bytes(out)


def enc_symbol(f, start):
    """EncSymbol precomputation, mirroring rust/src/rans/symbol.rs."""
    assert 1 <= f <= SCALE
    shift = max(f - 1, 0).bit_length()  # ceil(log2(f)); 0 for f == 1
    rcp = ((1 << (32 + shift)) + f - 1) // f  # ceil(2^(32+shift) / f)
    assert (1 << 32) <= rcp < (1 << 33)
    return {
        "x_max": ((STATE_LOWER >> SCALE_BITS) << 16) * f,
        "rcp_lo": rcp - (1 << 32),
        "rcp_shift": shift,
        "bias": start,
        "cmpl_freq": SCALE - f,
        "freq": f,
    }


def rans_encode_recip(symbols, freq, cdf):
    """The division-free encoder: widening multiply + shift per symbol."""
    table = [enc_symbol(f, c) if f > 0 else None for f, c in zip(freq, cdf)]
    state = STATE_LOWER
    rev = bytearray()
    for sym in reversed(symbols):
        e = table[sym]
        if state >= e["x_max"]:  # single branch: at most one flush
            rev.append((state >> 8) & 0xFF)
            rev.append(state & 0xFF)
            state >>= 16
        q = ((state + ((state * e["rcp_lo"]) >> 32)) >> e["rcp_shift"]) & MASK32
        state = state + e["bias"] + q * e["cmpl_freq"]
        assert state <= MASK32
    out = bytearray(struct.pack("<I", state))
    out.extend(reversed(rev))
    return bytes(out)


def rans_decode(data, count, freq, cdf):
    """Fused-table decoder (one entry per slot, single-branch renorm)."""
    slot_sym = [0] * SCALE
    for s in range(len(freq)):
        for slot in range(cdf[s], cdf[s + 1]):
            slot_sym[slot] = s
    state = struct.unpack("<I", data[0:4])[0]
    pos = 4
    out = []
    for _ in range(count):
        slot = state & (SCALE - 1)
        sym = slot_sym[slot]
        state = freq[sym] * (state >> SCALE_BITS) + slot - cdf[sym]
        if state < STATE_LOWER:
            assert pos + 2 <= len(data), "truncated"
            state = (state << 16) | data[pos] | (data[pos + 1] << 8)
            pos += 2
        out.append(sym)
    assert state == STATE_LOWER and pos == len(data)
    return out


# ----------------------------------------- multi-state rANS (v2 streams)
#
# N independent coder states inside one lane, round-robin: symbol i is
# coded by state i % N. All states share ONE byte stream (rans_static's
# single-stream interleaving). Wire layout of a lane payload:
#
#   [u32 LE state_0] ... [u32 LE state_{N-1}] [renorm bytes, decode order]
#
# The encoder walks symbols in reverse; whichever state renormalizes
# pushes (hi, lo) onto one shared reverse buffer; final states are
# written LE in state order 0..N-1 followed by the buffer reversed
# wholesale. N = 1 is byte-identical to the scalar stream. This mirrors
# rust/src/rans/multistate.rs exactly.


def rans_encode_multistate(symbols, freq, cdf, n):
    """N-state interleaved encoder (division-free, shared byte stream)."""
    table = [enc_symbol(f, c) if f > 0 else None for f, c in zip(freq, cdf)]
    states = [STATE_LOWER] * n
    rev = bytearray()
    for i in range(len(symbols) - 1, -1, -1):
        e = table[symbols[i]]
        j = i % n
        s = states[j]
        if s >= e["x_max"]:  # single branch: at most one flush per state
            rev.append((s >> 8) & 0xFF)
            rev.append(s & 0xFF)
            s >>= 16
        q = ((s + ((s * e["rcp_lo"]) >> 32)) >> e["rcp_shift"]) & MASK32
        s = s + e["bias"] + q * e["cmpl_freq"]
        assert s <= MASK32
        states[j] = s
    out = bytearray()
    for s in states:
        out.extend(struct.pack("<I", s))
    out.extend(reversed(rev))
    return bytes(out)


def rans_decode_multistate(data, count, freq, cdf, n):
    """N-state interleaved decoder (forward, same i % N schedule)."""
    slot_sym = [0] * SCALE
    for s in range(len(freq)):
        for slot in range(cdf[s], cdf[s + 1]):
            slot_sym[slot] = s
    assert len(data) >= 4 * n, "shorter than state words"
    states = list(struct.unpack("<" + "I" * n, data[0 : 4 * n]))
    pos = 4 * n
    out = []
    for i in range(count):
        j = i % n
        state = states[j]
        slot = state & (SCALE - 1)
        sym = slot_sym[slot]
        state = freq[sym] * (state >> SCALE_BITS) + slot - cdf[sym]
        if state < STATE_LOWER:
            assert pos + 2 <= len(data), "truncated"
            state = (state << 16) | data[pos] | (data[pos + 1] << 8)
            pos += 2
        states[j] = state
        out.append(sym)
    assert all(s == STATE_LOWER for s in states) and pos == len(data)
    return out


# ------------------------------------------- f16 / bf16 reference conversions
#
# Mirrors rust/src/tensor/half.rs bit for bit. Widening is exact;
# narrowing is round-to-nearest-even; NaNs keep their top payload bits
# (quiet bit forced if the payload would vanish), which makes every
# half -> f32 -> half round trip the identity. Validated here against
# CPython's native binary16 codec (struct '<e') for all finite values,
# and pinned for the Rust side by the CRC table in half_conv_crcs.hex.


def f16_bits_to_f32_bits(h):
    sign = (h & 0x8000) << 16
    exp = (h >> 10) & 0x1F
    man = h & 0x03FF
    if exp == 0:
        if man == 0:
            return sign
        shift = 0
        m = man
        while m < 0x400:  # renormalize the subnormal significand
            m <<= 1
            shift += 1
        exp32 = 113 - shift
        man32 = (man << (shift + 13)) & 0x007FFFFF
        return sign | (exp32 << 23) | man32
    if exp == 0x1F:
        return sign | 0x7F800000 | (man << 13)
    return sign | ((exp + 112) << 23) | (man << 13)


def f32_bits_to_f16_bits(bits):
    sign = (bits >> 16) & 0x8000
    absb = bits & 0x7FFFFFFF
    if absb >= 0x7F800000:
        if absb == 0x7F800000:
            return sign | 0x7C00
        payload = (absb >> 13) & 0x3FF
        return sign | 0x7C00 | (payload if payload else 0x200)
    exp32 = (absb >> 23) - 127
    man32 = absb & 0x007FFFFF
    if exp32 >= 16:
        return sign | 0x7C00
    if exp32 >= -14:
        base = ((exp32 + 15) << 10) | (man32 >> 13)
        rnd = man32 & 0x1000
        sticky = man32 & 0x0FFF
        lsb = man32 & 0x2000
        if rnd and (sticky or lsb):
            base += 1
        return sign | base
    if exp32 < -25:
        return sign
    man = man32 | 0x00800000
    shift = -exp32 - 1
    out = man >> shift
    rem = man & ((1 << shift) - 1)
    half = 1 << (shift - 1)
    if rem > half or (rem == half and (out & 1)):
        out += 1
    return sign | out


def bf16_bits_to_f32_bits(b):
    return b << 16


def f32_bits_to_bf16_bits(bits):
    absb = bits & 0x7FFFFFFF
    if absb > 0x7F800000:
        out = (bits >> 16) & 0xFFFF
        if out & 0x7F == 0:
            out |= 0x40
        return out
    rnd = 0x7FFF + ((bits >> 16) & 1)
    return ((bits + rnd) >> 16) & 0xFFFF


def narrowing_sweep_inputs():
    """The deterministic f32 bit-pattern sweep the f32->f16/bf16 CRC
    goldens cover; mirrored exactly in rust/tests/dtype_tensor.rs.
    Structured part: every exponent x {empty, min, round-bit, sticky,
    lsb, near-full, implicit-carry, full} mantissas x both signs.
    Random part: 2^18 LCG draws (high 32 bits of a 64-bit LCG)."""
    for e in range(256):
        for m in (0, 1, 0x1000, 0x0FFF, 0x2000, 0x3FFFFF, 0x400000, 0x7FFFFF):
            for s in (0, 1):
                yield (s << 31) | (e << 23) | m
    lcg = 0x0DD015EA5E
    for _ in range(1 << 18):
        lcg = (lcg * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        yield lcg >> 32


def validate_half_conversions():
    """Exhaustive checks of the reference conversions against CPython's
    native binary16 codec, plus the round-trip identities the Rust test
    wall relies on."""
    for h in range(1 << 16):
        w = f16_bits_to_f32_bits(h)
        if (h & 0x7C00) == 0x7C00 and (h & 0x03FF):
            assert w & 0x7FFFFFFF > 0x7F800000, f"f16 NaN {h:#06x} widened non-NaN"
        else:
            # struct's binary16 codec is the independent oracle for all
            # non-NaN values (payloads do not survive float()).
            val = struct.unpack("<e", struct.pack("<H", h))[0]
            assert struct.unpack("<I", struct.pack("<f", val))[0] == w, f"h={h:#06x}"
        assert f32_bits_to_f16_bits(w) == h, f"f16 roundtrip {h:#06x}"
    for b in range(1 << 16):
        assert f32_bits_to_bf16_bits(bf16_bits_to_f32_bits(b)) == b, f"bf16 {b:#06x}"
    # Narrowing vs struct on the structured sweep (finite results only).
    checked = 0
    for bits in narrowing_sweep_inputs():
        absb = bits & 0x7FFFFFFF
        if absb > 0x7F800000:
            out = f32_bits_to_f16_bits(bits)
            assert (out & 0x7C00) == 0x7C00 and (out & 0x3FF), "NaN lost"
            continue
        val = struct.unpack("<f", struct.pack("<I", bits))[0]
        try:
            want = struct.unpack("<H", struct.pack("<e", val))[0]
        except OverflowError:
            want = 0x7C00 | ((bits >> 16) & 0x8000)
        assert f32_bits_to_f16_bits(bits) == want, f"bits={bits:#010x}"
        checked += 1
    print(f"half conversions OK (f16/bf16 exhaustive; {checked} narrowing patterns vs struct)")


def emit_half_conv_crcs():
    """Four CRC-32s pinning the conversion tables for the Rust side:
    f16->f32 (all 2^16), bf16->f32 (all 2^16), f32->f16 and f32->bf16
    over narrowing_sweep_inputs(). Each table is the LE byte stream of
    the outputs in input order."""
    t = bytearray()
    for h in range(1 << 16):
        t.extend(struct.pack("<I", f16_bits_to_f32_bits(h)))
    crc_f16_w = zlib.crc32(bytes(t))
    t = bytearray()
    for b in range(1 << 16):
        t.extend(struct.pack("<I", bf16_bits_to_f32_bits(b)))
    crc_bf16_w = zlib.crc32(bytes(t))
    t16 = bytearray()
    tbf = bytearray()
    for bits in narrowing_sweep_inputs():
        t16.extend(struct.pack("<H", f32_bits_to_f16_bits(bits)))
        tbf.extend(struct.pack("<H", f32_bits_to_bf16_bits(bits)))
    crc_f16_n = zlib.crc32(bytes(t16))
    crc_bf16_n = zlib.crc32(bytes(tbf))
    out = struct.pack("<IIII", crc_f16_w, crc_bf16_w, crc_f16_n, crc_bf16_n)
    emit("half_conv_crcs.hex", out)


# ------------------------------------------------------ SHA-256 / HMAC
#
# Pure-Python replica of rust/src/util/sha256.rs, differentially
# validated against CPython's hashlib/hmac (the independent oracle) and
# used to emit the committed digest vectors the Rust registry tests pin.

SHA256_H0 = [
    0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A,
    0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19,
]
SHA256_K = [
    0x428A2F98, 0x71374491, 0xB5C0FBCF, 0xE9B5DBA5,
    0x3956C25B, 0x59F111F1, 0x923F82A4, 0xAB1C5ED5,
    0xD807AA98, 0x12835B01, 0x243185BE, 0x550C7DC3,
    0x72BE5D74, 0x80DEB1FE, 0x9BDC06A7, 0xC19BF174,
    0xE49B69C1, 0xEFBE4786, 0x0FC19DC6, 0x240CA1CC,
    0x2DE92C6F, 0x4A7484AA, 0x5CB0A9DC, 0x76F988DA,
    0x983E5152, 0xA831C66D, 0xB00327C8, 0xBF597FC7,
    0xC6E00BF3, 0xD5A79147, 0x06CA6351, 0x14292967,
    0x27B70A85, 0x2E1B2138, 0x4D2C6DFC, 0x53380D13,
    0x650A7354, 0x766A0ABB, 0x81C2C92E, 0x92722C85,
    0xA2BFE8A1, 0xA81A664B, 0xC24B8B70, 0xC76C51A3,
    0xD192E819, 0xD6990624, 0xF40E3585, 0x106AA070,
    0x19A4C116, 0x1E376C08, 0x2748774C, 0x34B0BCB5,
    0x391C0CB3, 0x4ED8AA4A, 0x5B9CCA4F, 0x682E6FF3,
    0x748F82EE, 0x78A5636F, 0x84C87814, 0x8CC70208,
    0x90BEFFFA, 0xA4506CEB, 0xBEF9A3F7, 0xC67178F2,
]


def _rotr32(x, n):
    return ((x >> n) | (x << (32 - n))) & MASK32


def sha256_ref(msg):
    """FIPS 180-4 SHA-256, replicating util/sha256.rs compress()."""
    h = list(SHA256_H0)
    bit_len = len(msg) * 8
    msg = msg + b"\x80" + b"\x00" * ((55 - len(msg)) % 64)
    msg += bit_len.to_bytes(8, "big")
    for off in range(0, len(msg), 64):
        w = [int.from_bytes(msg[off + 4 * i:off + 4 * i + 4], "big") for i in range(16)]
        for i in range(16, 64):
            s0 = _rotr32(w[i - 15], 7) ^ _rotr32(w[i - 15], 18) ^ (w[i - 15] >> 3)
            s1 = _rotr32(w[i - 2], 17) ^ _rotr32(w[i - 2], 19) ^ (w[i - 2] >> 10)
            w.append((w[i - 16] + s0 + w[i - 7] + s1) & MASK32)
        a, b, c, d, e, f, g, hh = h
        for i in range(64):
            s1 = _rotr32(e, 6) ^ _rotr32(e, 11) ^ _rotr32(e, 25)
            ch = (e & f) ^ (~e & g)
            t1 = (hh + s1 + ch + SHA256_K[i] + w[i]) & MASK32
            s0 = _rotr32(a, 2) ^ _rotr32(a, 13) ^ _rotr32(a, 22)
            maj = (a & b) ^ (a & c) ^ (b & c)
            t2 = (s0 + maj) & MASK32
            hh, g, f, e, d, c, b, a = g, f, e, (d + t1) & MASK32, c, b, a, (t1 + t2) & MASK32
        h = [(x + y) & MASK32 for x, y in zip(h, (a, b, c, d, e, f, g, hh))]
    return b"".join(x.to_bytes(4, "big") for x in h)


def hmac_sha256_ref(key, msg):
    """RFC 2104 HMAC over sha256_ref, replicating registry/signer.rs."""
    if len(key) > 64:
        key = sha256_ref(key)
    key = key + b"\x00" * (64 - len(key))
    ipad = bytes(k ^ 0x36 for k in key)
    opad = bytes(k ^ 0x5C for k in key)
    return sha256_ref(opad + sha256_ref(ipad + msg))


def lcg_bytes(seed, n):
    """Deterministic byte string; mirrored in golden_vectors.rs."""
    lcg = seed
    out = bytearray()
    for _ in range(n):
        lcg = (lcg * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        out.append((lcg >> 33) & 0xFF)
    return bytes(out)


# Lengths exercised by both the committed vectors and the Rust pin; they
# straddle every padding boundary (55/56/63/64) plus multi-block sizes.
SHA256_VECTOR_LENS = [0, 1, 3, 55, 56, 57, 63, 64, 65, 127, 128, 129, 1000, 4096]
# (key_len, msg_len) pairs for HMAC: empty, short, block-sized, and
# over-block keys (the key > 64 path hashes the key first).
HMAC_VECTOR_SHAPES = [(0, 0), (1, 1), (20, 50), (32, 117), (64, 64), (65, 200), (131, 54)]


def validate_sha256():
    """Differential wall for the hand-rolled SHA-256/HMAC:

    1. replica vs hashlib over every length 0..257 and LCG-chosen
       lengths up to 4096 (covers all padding residues many times over);
    2. FIPS 180-4 known answers, including the million-'a' vector;
    3. HMAC replica vs CPython's hmac module across key shapes.
    """
    import hashlib
    import hmac as hmac_mod

    lcg = 0x5EED5EED
    lens = list(range(258))
    for _ in range(160):
        lcg = (lcg * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        lens.append((lcg >> 33) % 4097)
    for i, n in enumerate(lens):
        m = lcg_bytes(0xD16E57 + i, n)
        assert sha256_ref(m) == hashlib.sha256(m).digest(), f"len {n}"
    assert sha256_ref(b"").hex() == (
        "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
    )
    assert sha256_ref(b"abc").hex() == (
        "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
    )
    assert sha256_ref(b"a" * 1_000_000).hex() == (
        "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
    )
    for i, (kl, ml) in enumerate(HMAC_VECTOR_SHAPES):
        key = lcg_bytes(0x4B450000 + i, kl)
        msg = lcg_bytes(0x6D560000 + i, ml)
        want = hmac_mod.new(key, msg, hashlib.sha256).digest()
        assert hmac_sha256_ref(key, msg) == want, f"hmac shape {kl}/{ml}"
    # RFC 4231 test cases 1–2 (the ones the Rust signer pins).
    assert hmac_sha256_ref(b"\x0b" * 20, b"Hi There").hex() == (
        "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
    )
    assert hmac_sha256_ref(b"Jefe", b"what do ya want for nothing?").hex() == (
        "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
    )
    print(f"sha256/hmac OK ({len(lens)} lengths vs hashlib, "
          f"{len(HMAC_VECTOR_SHAPES)} hmac shapes vs hmac)")


def emit_sha256_vectors():
    """Concatenated digests of LCG messages (and HMACs of LCG key/msg
    pairs) that rust/tests/golden_vectors.rs recomputes and pins.
    Emitted from hashlib/hmac directly so the committed bytes are
    oracle-authored, not replica-authored."""
    import hashlib
    import hmac as hmac_mod

    out = bytearray()
    for i, n in enumerate(SHA256_VECTOR_LENS):
        out.extend(hashlib.sha256(lcg_bytes(0x5A0000 + i, n)).digest())
    emit("sha256_lcg.hex", bytes(out))
    out = bytearray()
    for i, (kl, ml) in enumerate(HMAC_VECTOR_SHAPES):
        key = lcg_bytes(0x4B450000 + i, kl)
        msg = lcg_bytes(0x6D560000 + i, ml)
        out.extend(hmac_mod.new(key, msg, hashlib.sha256).digest())
    emit("hmac_lcg.hex", bytes(out))


# -------------------------------------------------- reciprocal validation


def validate_reciprocal():
    """q must equal x // f for every f in 1..=SCALE at adversarial x."""
    lcg = 0x123456789ABCDEF
    for f in range(1, SCALE + 1):
        e = enc_symbol(f, 0)
        xs = set()
        x_max = e["x_max"]  # states at transition time are < x_max
        hi = min(x_max, 1 << 32)
        # Boundaries where off-by-one failures live: around multiples of f
        # near the top of the state range, plus the interval edges.
        for k in (hi // f, hi // f - 1, (hi // f) // 2, 1, 2):
            for d in (-1, 0, 1):
                x = k * f + d
                if 0 <= x < hi:
                    xs.add(x)
        xs.add(hi - 1)
        xs.add(STATE_LOWER)
        for _ in range(48):
            lcg = (lcg * 6364136223846793005 + 1442695040888963407) % (1 << 64)
            xs.add((lcg >> 32) % hi)
        for x in xs:
            q = ((x + ((x * e["rcp_lo"]) >> 32)) >> e["rcp_shift"]) & MASK32
            assert q == x // f, f"f={f} x={x}: got {q}, want {x // f}"
    print(f"reciprocal exact-division check OK for all f in 1..={SCALE}")


def validate_encoders():
    """Both encoders must agree byte-for-byte; decode must roundtrip."""
    lcg = 0xC0FFEE
    for alphabet, n in ((2, 400), (16, 3000), (64, 5000), (256, 8000)):
        symbols = []
        for _ in range(n):
            lcg = (lcg * 6364136223846793005 + 1442695040888963407) % (1 << 64)
            # Skew: half the draws collapse to symbol 0.
            symbols.append(
                0 if (lcg >> 20) & 1 else (lcg >> 33) % alphabet
            )
        counts = [0] * alphabet
        for s in symbols:
            counts[s] += 1
        freq = from_counts(counts)
        cdf = cdf_of(freq)
        a = rans_encode_div(symbols, freq, cdf)
        b = rans_encode_recip(symbols, freq, cdf)
        assert a == b, f"encoder mismatch: alphabet={alphabet} n={n}"
        assert rans_decode(a, n, freq, cdf) == symbols
    # Degenerate full-mass table (freq == SCALE for one symbol).
    freq = [SCALE]
    cdf = cdf_of(freq)
    sym = [0] * 10000
    a = rans_encode_div(sym, freq, cdf)
    b = rans_encode_recip(sym, freq, cdf)
    assert a == b and rans_decode(a, len(sym), freq, cdf) == sym
    print("div/mod and reciprocal encoders byte-identical; roundtrips OK")


def validate_multistate():
    """N-state streams: N=1 byte-identical to scalar; roundtrips across
    N, lengths straddling the round-robin edges, and alphabets."""
    lcg = 0xFACADE
    for alphabet in (2, 16, 64, 256):
        symbols = []
        for _ in range(5000):
            lcg = (lcg * 6364136223846793005 + 1442695040888963407) % (1 << 64)
            symbols.append(0 if (lcg >> 20) & 1 else (lcg >> 33) % alphabet)
        counts = [0] * alphabet
        for s in symbols:
            counts[s] += 1
        freq = from_counts(counts)
        cdf = cdf_of(freq)
        assert rans_encode_multistate(symbols, freq, cdf, 1) == rans_encode_recip(
            symbols, freq, cdf
        ), f"N=1 must be byte-identical to scalar (alphabet={alphabet})"
        for n in (1, 2, 4, 8):
            for cut in (0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, len(symbols)):
                part = symbols[:cut]
                p = rans_encode_multistate(part, freq, cdf, n)
                assert rans_decode_multistate(p, len(part), freq, cdf, n) == part, (
                    f"multistate roundtrip failed: alphabet={alphabet} n={n} len={cut}"
                )
    print("multi-state streams: N=1 == scalar; roundtrips OK for N in {1,2,4,8}")


# ----------------------------------------------------- pipeline replica


def lane_spans(count, lanes):
    lanes = max(lanes, 1)
    base, extra = divmod(count, lanes)
    spans, start = [], 0
    for i in range(lanes):
        ln = base + (1 if i < extra else 0)
        spans.append((start, start + ln))
        start += ln
    return spans


def assemble_stream(lanes, symbol_count, payloads):
    out = bytearray()
    write_varint(out, lanes)
    write_varint(out, symbol_count)
    for p in payloads:
        write_varint(out, len(p))
    for p in payloads:
        out.extend(p)
    return bytes(out)


def assemble_stream_v2(lanes, states, symbol_count, payloads):
    """v2 layout: zero marker + states-per-lane, then the v1 framing.

    A v1 stream always starts with lane_count >= 1, so the leading zero
    varint unambiguously flags the v2 layout.
    """
    out = bytearray()
    write_varint(out, 0)
    write_varint(out, states)
    write_varint(out, lanes)
    write_varint(out, symbol_count)
    for p in payloads:
        write_varint(out, len(p))
    for p in payloads:
        out.extend(p)
    return bytes(out)


def mod_csr(symbols, n_rows, n_cols, background):
    values, cols, row_counts = [], [], []
    for r in range(n_rows):
        cnt = 0
        for c in range(n_cols):
            s = symbols[r * n_cols + c]
            if s != background:
                values.append(s)
                cols.append(c)
                cnt += 1
        row_counts.append(cnt)
    return values, cols, row_counts


def serialize_table(buf: bytearray, freq) -> None:
    write_varint(buf, len(freq))
    for f in freq:
        write_varint(buf, f)


def golden_symbols(q, t):
    """Deterministic quantized tensor; mirrored in golden_vectors.rs."""
    alphabet = 1 << q
    lcg = 0xC0FFEE + q
    out = []
    for _ in range(t):
        lcg = (lcg * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        if (lcg >> 29) & 7 < 5:
            out.append(1)  # background (zero point)
        else:
            out.append((lcg >> 33) % alphabet)
    return out


def container_v1(q, scale_bytes, zero, orig_len, n_rows, nnz, alphabet, freq, payload,
                 dtype=0):
    """RSC1 container. dtype 0 (f32) keeps the legacy version-1 header
    byte-identically; dtype 1 (f16) / 2 (bf16) emit version 2 with a
    dtype tag byte after q — mirroring pipeline/container.rs."""
    out = bytearray(b"RSC1")
    if dtype == 0:
        out.append(1)
        out.append(q)
    else:
        out.append(2)
        out.append(q)
        out.append(dtype)
    out.extend(scale_bytes)
    write_zigzag(out, zero)
    write_varint(out, orig_len)
    write_varint(out, n_rows)
    write_varint(out, nnz)
    write_varint(out, alphabet)
    serialize_table(out, freq)
    write_varint(out, len(payload))
    out.extend(payload)
    out.extend(struct.pack("<I", zlib.crc32(bytes(out))))
    return bytes(out)


def container_v2(q, scale_bytes, zero, orig_len, n_rows, nnz, alphabet, freq, chunks,
                 dtype=0):
    """RSC2 chunked container. dtype 0 keeps the legacy version-2
    header; non-zero dtypes emit version 3 with a tag byte after q —
    mirroring engine/chunked.rs."""
    head = bytearray(b"RSC2")
    if dtype == 0:
        head.append(2)
        head.append(q)
    else:
        head.append(3)
        head.append(q)
        head.append(dtype)
    head.extend(scale_bytes)
    write_zigzag(head, zero)
    write_varint(head, orig_len)
    write_varint(head, n_rows)
    write_varint(head, nnz)
    write_varint(head, alphabet)
    serialize_table(head, freq)
    write_varint(head, len(chunks))
    for symbol_count, payload in chunks:
        write_varint(head, symbol_count)
        write_varint(head, len(payload))
        head.extend(struct.pack("<I", zlib.crc32(payload)))
    head.extend(struct.pack("<I", zlib.crc32(bytes(head))))
    for _, payload in chunks:
        head.extend(payload)
    return bytes(head)


def emit(name, data):
    path = os.path.join(OUT_DIR, name)
    with open(path, "w") as fh:
        fh.write(data.hex())
        fh.write("\n")
    print(f"wrote {name}: {len(data)} bytes")


def generate_goldens():
    t, n_rows = 1536, 32
    n_cols = t // n_rows
    zero = 1  # background symbol == zero point
    scale_bytes = struct.pack("<f", 0.5)
    chunk_symbols = 257

    for q in (2, 4, 8):
        symbols = golden_symbols(q, t)
        values, cols, row_counts = mod_csr(symbols, n_rows, n_cols, zero)
        nnz = len(values)
        d = values + cols + row_counts
        alphabet = max(1 << q, n_cols, max(row_counts) + 1)
        counts = [0] * alphabet
        for s in d:
            counts[s] += 1
        freq = from_counts(counts)
        cdf = cdf_of(freq)

        for lanes in (1, 8):
            payloads = []
            for lo, hi in lane_spans(len(d), lanes):
                p = rans_encode_recip(d[lo:hi], freq, cdf)
                assert p == rans_encode_div(d[lo:hi], freq, cdf)
                assert rans_decode(p, hi - lo, freq, cdf) == d[lo:hi]
                payloads.append(p)
            stream = assemble_stream(lanes, len(d), payloads)
            emit(
                f"v1_q{q}_lanes{lanes}.hex",
                container_v1(q, scale_bytes, zero, t, n_rows, nnz, alphabet, freq, stream),
            )

        # v2 multi-state streams inside the same RSC1 container
        # (single lane; the multi-lane × multi-state cases are below).
        # N = 8 is the AVX2 SIMD-decoder width; its vectors pin the wire
        # format the Rust SIMD and scalar decoders must both honor.
        for n_states in (2, 4, 8):
            p = rans_encode_multistate(d, freq, cdf, n_states)
            assert rans_decode_multistate(p, len(d), freq, cdf, n_states) == d
            stream = assemble_stream_v2(1, n_states, len(d), [p])
            emit(
                f"v2s{n_states}_q{q}.hex",
                container_v1(q, scale_bytes, zero, t, n_rows, nnz, alphabet, freq, stream),
            )

        # Multi-lane × multi-state: 8 lanes, 4 states per lane.
        payloads = []
        for lo, hi in lane_spans(len(d), 8):
            p = rans_encode_multistate(d[lo:hi], freq, cdf, 4)
            assert rans_decode_multistate(p, hi - lo, freq, cdf, 4) == d[lo:hi]
            payloads.append(p)
        stream = assemble_stream_v2(8, 4, len(d), payloads)
        emit(
            f"v2s4_q{q}_lanes8.hex",
            container_v1(q, scale_bytes, zero, t, n_rows, nnz, alphabet, freq, stream),
        )

        # Multi-lane × 8-state (one representative case per AVX2 width):
        # 8 lanes, 8 states per lane, Q = 4 only.
        if q == 4:
            payloads = []
            for lo, hi in lane_spans(len(d), 8):
                p = rans_encode_multistate(d[lo:hi], freq, cdf, 8)
                assert rans_decode_multistate(p, hi - lo, freq, cdf, 8) == d[lo:hi]
                payloads.append(p)
            stream = assemble_stream_v2(8, 8, len(d), payloads)
            emit(
                "v2s8_q4_lanes8.hex",
                container_v1(q, scale_bytes, zero, t, n_rows, nnz, alphabet, freq, stream),
            )

        n_chunks = max(min((len(d) + chunk_symbols - 1) // chunk_symbols, 1 << 20), 1)
        chunks = []
        for lo, hi in lane_spans(len(d), n_chunks):
            chunks.append((hi - lo, rans_encode_recip(d[lo:hi], freq, cdf)))
        emit(
            f"v2_q{q}.hex",
            container_v2(q, scale_bytes, zero, t, n_rows, nnz, alphabet, freq, chunks),
        )

    # Dtype-tagged containers (the f16/bf16 LM wire format): the same
    # Q=4 golden symbol stream under every non-f32 header shape — v1
    # single- and multi-lane, a v2 multi-state stream inside a dtyped
    # RSC1, and both dtypes through the chunked RSC2. Symbols and
    # payloads are dtype-independent by design (the tag only names the
    # reconstruction target), so these pin exactly the header bytes.
    q = 4
    symbols = golden_symbols(q, t)
    values, cols, row_counts = mod_csr(symbols, n_rows, n_cols, zero)
    nnz = len(values)
    d = values + cols + row_counts
    alphabet = max(1 << q, n_cols, max(row_counts) + 1)
    counts = [0] * alphabet
    for s in d:
        counts[s] += 1
    freq = from_counts(counts)
    cdf = cdf_of(freq)
    F16, BF16 = 1, 2
    for dtype, name in ((F16, "f16"), (BF16, "bf16")):
        payloads = [
            rans_encode_recip(d[lo:hi], freq, cdf) for lo, hi in lane_spans(len(d), 8)
        ]
        stream = assemble_stream(8, len(d), payloads)
        emit(
            f"v1{name}_q4_lanes8.hex",
            container_v1(q, scale_bytes, zero, t, n_rows, nnz, alphabet, freq, stream,
                         dtype=dtype),
        )
        n_chunks = max(min((len(d) + chunk_symbols - 1) // chunk_symbols, 1 << 20), 1)
        chunks = []
        for lo, hi in lane_spans(len(d), n_chunks):
            chunks.append((hi - lo, rans_encode_recip(d[lo:hi], freq, cdf)))
        emit(
            f"v2c{name}_q4.hex",
            container_v2(q, scale_bytes, zero, t, n_rows, nnz, alphabet, freq, chunks,
                         dtype=dtype),
        )
    # Single-lane bf16 v1, and bf16 with a 4-state v2 stream layout
    # (dtype tag and stream layout are orthogonal axes).
    stream = assemble_stream(1, len(d), [rans_encode_recip(d, freq, cdf)])
    emit(
        "v1bf16_q4_lanes1.hex",
        container_v1(q, scale_bytes, zero, t, n_rows, nnz, alphabet, freq, stream,
                     dtype=BF16),
    )
    p = rans_encode_multistate(d, freq, cdf, 4)
    assert rans_decode_multistate(p, len(d), freq, cdf, 4) == d
    stream = assemble_stream_v2(1, 4, len(d), [p])
    emit(
        "v1bf16s4_q4.hex",
        container_v1(q, scale_bytes, zero, t, n_rows, nnz, alphabet, freq, stream,
                     dtype=BF16),
    )

    # Raw single-lane scalar streams: the codec layer alone, no container.
    for q in (2, 4, 8):
        alphabet = 1 << q
        symbols = golden_symbols(q, 4096)
        counts = [0] * alphabet
        for s in symbols:
            counts[s] += 1
        freq = from_counts(counts)
        cdf = cdf_of(freq)
        p = rans_encode_recip(symbols, freq, cdf)
        assert p == rans_encode_div(symbols, freq, cdf)
        assert rans_decode(p, len(symbols), freq, cdf) == symbols
        emit(f"raw_q{q}.hex", p)

    # Raw multi-state lane streams: the multistate codec layer alone
    # (no lane framing, no container) over the Q=4 golden stream.
    alphabet = 1 << 4
    symbols = golden_symbols(4, 4096)
    counts = [0] * alphabet
    for s in symbols:
        counts[s] += 1
    freq = from_counts(counts)
    cdf = cdf_of(freq)
    for n_states in (2, 4, 8):
        p = rans_encode_multistate(symbols, freq, cdf, n_states)
        assert rans_decode_multistate(p, len(symbols), freq, cdf, n_states) == symbols
        emit(f"raw_ms{n_states}_q4.hex", p)


def main():
    validate_half_conversions()
    validate_sha256()
    validate_reciprocal()
    validate_encoders()
    validate_multistate()
    emit_half_conv_crcs()
    emit_sha256_vectors()
    generate_goldens()
    print("all golden vectors written")


if __name__ == "__main__":
    sys.exit(main())
