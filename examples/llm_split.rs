//! LLM split-computing demo: Llama-Mini over an in-process transport.
//!
//! Mirrors the paper's §4.2 LLM deployment: the edge runs the first
//! half of the decoder stack, ships compressed hidden states, the cloud
//! finishes and returns per-token logits; the edge scores the four
//! choices of each multiple-choice item.
//!
//! ```bash
//! make artifacts && cargo run --release --example llm_split [task] [n_items]
//! ```

use std::sync::Arc;

use rans_sc::coordinator::{CloudNode, EdgeConfig, InProcTransport, LmEdgeNode, Transport};
use rans_sc::data::{lm_tasks::score_choices, McTask};
use rans_sc::runtime::{Engine, ExecPool, LmSplitExec, Manifest};
use rans_sc::util::stats::Summary;

const MODEL: &str = "llama_mini_s";
const Q: u8 = 6;

fn main() -> rans_sc::Result<()> {
    let task_name = std::env::args().nth(1).unwrap_or_else(|| "retrieval".into());
    let n_items: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(16);
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    let cloud = Arc::new(CloudNode::new(&dir)?);
    let (edge_end, mut cloud_end) = InProcTransport::pair();
    let cloud_thread = {
        let cloud = Arc::clone(&cloud);
        std::thread::spawn(move || cloud.serve_transport(&mut cloud_end as &mut dyn Transport))
    };

    let manifest = Manifest::load(&dir)?;
    let engine = Arc::new(Engine::cpu()?);
    let pool = ExecPool::new(engine, dir.as_str());
    let exec = Arc::new(LmSplitExec::load(&pool, &manifest, MODEL)?);
    let lm = exec.entry.clone();
    let task_file = lm
        .tasks
        .iter()
        .find(|t| t.name == task_name)
        .ok_or_else(|| rans_sc::Error::invalid(format!("unknown task '{task_name}'")))?;
    let task = McTask::load(manifest.resolve(&task_file.path))?;
    let edge = LmEdgeNode::new(Arc::clone(&exec), edge_end, EdgeConfig::paper(MODEL, lm.split, lm.batch, Q));

    println!(
        "{MODEL} (dim {}, split after block {}) on task '{task_name}', Q={Q}",
        lm.dim, lm.split
    );
    println!(
        "build-time baseline accuracy: {:.2}%",
        lm.baseline_accuracy.get(&task_name).copied().unwrap_or(f64::NAN) * 100.0
    );

    let mut correct = 0usize;
    let mut bytes = Summary::new();
    let mut raw_bytes = Summary::new();
    let mut tx = Summary::new();
    let mut tx_raw = Summary::new();
    let n = n_items.min(task.items.len());
    for item in task.items.iter().take(n) {
        let tokens = task.item_batch(item);
        let out = edge.infer(&tokens)?;
        if score_choices(&out.logits, &task, item) == item.correct {
            correct += 1;
        }
        bytes.add(out.payload_bytes as f64);
        tx.add(out.breakdown.transfer_ms);

        let raw = edge.infer_raw(&tokens)?;
        raw_bytes.add(raw.payload_bytes as f64);
        tx_raw.add(raw.breakdown.transfer_ms);
    }
    println!(
        "accuracy over {n} items: {:.2}% | payload {:.1} KB vs {:.1} KB raw ({:.2}x) | \
         T_comm {:.2} ms vs {:.2} ms ({:.2}x)",
        correct as f64 / n as f64 * 100.0,
        bytes.mean() / 1000.0,
        raw_bytes.mean() / 1000.0,
        raw_bytes.mean() / bytes.mean(),
        tx.mean(),
        tx_raw.mean(),
        tx_raw.mean() / tx.mean()
    );

    drop(edge); // closes the in-proc link; cloud loop exits
    let _ = cloud_thread.join();
    Ok(())
}
