//! Quickstart: compress and decompress one intermediate-feature tensor.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Works without artifacts (synthetic IF); with `make artifacts` it uses
//! a real ResNet-Mini SL2 feature.

use rans_sc::eval::feature_tensor;
use rans_sc::pipeline::{compress, decompress, PipelineConfig};

fn main() -> rans_sc::Result<()> {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let (data, source) = feature_tensor(&dir, "resnet_mini_synth_a", 2)?;
    println!("feature: {} f32 ({} KB raw), source {source:?}", data.len(), data.len() * 4 / 1000);

    for q in [3u8, 4, 6, 8] {
        let cfg = PipelineConfig::paper(q);
        let t0 = std::time::Instant::now();
        let (bytes, stats) = compress(&data, &cfg)?;
        let enc_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = std::time::Instant::now();
        let restored = decompress(&bytes)?;
        let dec_ms = t1.elapsed().as_secs_f64() * 1e3;
        let max_err = data
            .iter()
            .zip(&restored)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!(
            "Q={q}: {:>8} B ({:>5.1}x) | reshape {}x{} | entropy {:.3} b/sym | \
             enc {enc_ms:.2} ms dec {dec_ms:.2} ms | max err {max_err:.4}",
            bytes.len(),
            (data.len() * 4) as f64 / bytes.len() as f64,
            stats.n_rows,
            stats.n_cols,
            stats.entropy,
        );
    }
    Ok(())
}
