//! Reshape-dimension explorer: walk Algorithm 1's search by hand.
//!
//! Prints every candidate the optimizer evaluates (descending N), the
//! early-stop point, and the exhaustive oracle for comparison — a
//! didactic view of §3.2–3.3.
//!
//! ```bash
//! cargo run --release --example reshape_explorer [Q]
//! ```

use rans_sc::eval::feature_tensor;
use rans_sc::quant::{quantize, QuantParams};
use rans_sc::reshape::{self, optimizer::OptimizerConfig};

fn main() -> rans_sc::Result<()> {
    let q: u8 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let (data, source) = feature_tensor(&dir, "resnet_mini_synth_a", 2)?;
    let params = QuantParams::fit(q, &data)?;
    let symbols = quantize(&data, &params);
    let t = symbols.len();
    println!("T = {t}, Q = {q}, zero symbol = {}, source {source:?}", params.zero_symbol());

    let cfg = OptimizerConfig::paper(q);
    let domain = reshape::optimizer::candidate_domain(t, &cfg);
    println!(
        "constrained domain: {} divisors in [{}, {}] (N > √T = {}, K ≤ 2^Q = {})",
        domain.len(),
        domain.first().unwrap_or(&0),
        domain.last().unwrap_or(&0),
        reshape::divisors::isqrt(t),
        1 << q
    );

    let out = reshape::optimize(&symbols, params.zero_symbol(), &cfg)?;
    println!("\n{:>10} {:>8} {:>10} {:>12} {:>14}", "N", "K", "nnz", "H (b/sym)", "T_tot (KB)");
    for c in &out.trace {
        let marker = if c.n == out.best.n { "  <- Ñ" } else { "" };
        println!(
            "{:>10} {:>8} {:>10} {:>12.3} {:>14.1}{marker}",
            c.n,
            c.k,
            c.nnz,
            c.entropy,
            c.t_tot_bits / 8e3
        );
    }
    println!(
        "\nAlgorithm 1: evaluated {}/{} candidates before early stop",
        out.evaluated, out.domain_size
    );

    let oracle = reshape::exhaustive_search(&symbols, params.zero_symbol(), &cfg, true)?;
    println!(
        "exhaustive oracle: N* = {} (T_tot {:.1} KB) vs Ñ = {} (T_tot {:.1} KB) — gap {:.2}%",
        oracle.best.n,
        oracle.best.t_tot_bits / 8e3,
        out.best.n,
        out.best.t_tot_bits / 8e3,
        (out.best.t_tot_bits / oracle.best.t_tot_bits - 1.0) * 100.0
    );
    Ok(())
}
