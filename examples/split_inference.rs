//! End-to-end split-computing driver (the repo's E2E validation run).
//!
//! Starts a cloud node on loopback TCP, connects an edge node, and
//! streams test-set requests through the full pipeline:
//!
//! ```text
//! edge: head HLO (Pallas quantize epilogue) → CSR+rANS container
//!   → TCP → cloud: decode → tail HLO (Pallas dequantize prologue) → logits
//! ```
//!
//! Phase 1: sequential batch-1 requests — accuracy + 4-factor latency
//! breakdown + simulated T_comm, compressed vs raw baseline.
//! Phase 2: concurrent clients through the bucketed dynamic batcher on
//! the batch-8 artifact — throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example split_inference [N]
//! ```

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use rans_sc::coordinator::{
    connect_tcp, Batcher, BatcherConfig, CloudNode, EdgeConfig, EdgeNode,
};
use rans_sc::data::VisionSet;
use rans_sc::runtime::{Engine, ExecPool, Manifest, VisionSplitExec};
use rans_sc::util::stats::Summary;

const MODEL: &str = "resnet_mini_synth_a";
const SL: usize = 2;
const Q: u8 = 4;

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn main() -> rans_sc::Result<()> {
    let n_requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // ---- cloud node on loopback ----
    let cloud = Arc::new(CloudNode::new(&dir)?);
    let listener = std::net::TcpListener::bind("127.0.0.1:0")
        .map_err(|e| rans_sc::Error::transport(format!("bind: {e}")))?;
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let cloud_thread = {
        let cloud = Arc::clone(&cloud);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || cloud.serve_tcp(listener, stop))
    };
    println!("cloud node on {addr}");

    // ---- edge side ----
    let manifest = Manifest::load(&dir)?;
    let engine = Arc::new(Engine::cpu()?);
    let pool = ExecPool::new(engine, dir.as_str());
    let exec = Arc::new(VisionSplitExec::load(&pool, &manifest, MODEL, SL, 1)?);
    let set = VisionSet::load(manifest.resolve(&exec.entry.test_data))?;
    let classes = exec.entry.num_classes;
    let edge = EdgeNode::new(
        Arc::clone(&exec),
        connect_tcp(&addr)?,
        EdgeConfig::paper(MODEL, SL, 1, Q),
    );

    // ---- phase 1: sequential batch-1, compressed vs raw ----
    println!("\n== phase 1: {n_requests} sequential requests (batch 1, Q={Q}) ==");
    let mut correct = 0usize;
    let mut correct_raw = 0usize;
    let mut bytes = Summary::new();
    let mut bytes_raw = Summary::new();
    let mut enc = Summary::new();
    let mut tx = Summary::new();
    let mut tx_raw = Summary::new();
    let mut dec = Summary::new();
    let mut comp = Summary::new();
    let wall = std::time::Instant::now();
    for i in 0..n_requests {
        let (xs, ys) = set.batch(i, 1);
        let out = edge.infer(&xs)?;
        if argmax(&out.logits[0..classes]) == ys[0] as usize {
            correct += 1;
        }
        bytes.add(out.payload_bytes as f64);
        enc.add(out.breakdown.encode_ms);
        tx.add(out.breakdown.transfer_ms);
        dec.add(out.breakdown.decode_ms);
        comp.add(out.breakdown.compute_ms);

        let raw = edge.infer_raw(&xs)?;
        if argmax(&raw.logits[0..classes]) == ys[0] as usize {
            correct_raw += 1;
        }
        bytes_raw.add(raw.payload_bytes as f64);
        tx_raw.add(raw.breakdown.transfer_ms);
    }
    let elapsed = wall.elapsed().as_secs_f64();
    println!(
        "accuracy: compressed {:.2}% vs raw baseline {:.2}% (build-time full model {:.2}%)",
        correct as f64 / n_requests as f64 * 100.0,
        correct_raw as f64 / n_requests as f64 * 100.0,
        exec.entry.baseline_accuracy * 100.0
    );
    println!(
        "payload: {:.0} B compressed vs {:.0} B raw ({:.1}x reduction)",
        bytes.mean(),
        bytes_raw.mean(),
        bytes_raw.mean() / bytes.mean()
    );
    println!(
        "simulated T_comm (ε-outage): {:.2} ms vs {:.2} ms raw ({:.1}x)",
        tx.mean(),
        tx_raw.mean(),
        tx_raw.mean() / tx.mean()
    );
    println!(
        "latency factors: encode {:.2} ms | decode {:.2} ms | tail compute {:.2} ms",
        enc.mean(),
        dec.mean(),
        comp.mean()
    );
    println!(
        "wall throughput (both paths, incl. raw baseline): {:.1} req/s",
        2.0 * n_requests as f64 / elapsed
    );
    let (hits, misses) = edge.plan_cache_stats();
    println!("reshape-plan cache: {hits} hits / {misses} misses");

    // ---- phase 2: concurrent clients through the batcher (batch-8) ----
    if exec.entry.split(SL, 8).is_ok() {
        println!("\n== phase 2: concurrent clients via bucketed batcher (buckets 1/8) ==");
        let exec8 = Arc::new(VisionSplitExec::load(&pool, &manifest, MODEL, SL, 8)?);
        let img_len = set.image_len();
        let batcher: Batcher<Vec<f32>, Vec<f32>> = Batcher::new(BatcherConfig {
            buckets: vec![1, 8],
            max_wait: std::time::Duration::from_millis(3),
            ..Default::default()
        });
        let worker = {
            let batcher = batcher.clone();
            let exec1 = Arc::clone(&exec);
            let exec8 = Arc::clone(&exec8);
            std::thread::spawn(move || {
                batcher.run(move |reqs, bucket| {
                    // Concatenate + pad to the bucket's static shape.
                    let n = reqs.len();
                    let mut flat = Vec::with_capacity(bucket * img_len);
                    for r in &reqs {
                        flat.extend_from_slice(r);
                    }
                    for _ in n..bucket {
                        flat.extend_from_slice(&reqs[n - 1]);
                    }
                    let exec = if bucket == 8 { &exec8 } else { &exec1 };
                    match exec
                        .run_head(&flat, Q)
                        .and_then(|(syms, p)| {
                            let cfg = rans_sc::pipeline::PipelineConfig::paper(Q);
                            let (c, _) = rans_sc::pipeline::compress_quantized(&syms, p, &cfg)?;
                            let (s2, p2) = rans_sc::pipeline::decompress_to_symbols(&c)?;
                            exec.run_tail(&s2, &p2)
                        }) {
                        Ok(logits) => {
                            let per = logits.len() / bucket;
                            (0..n).map(|i| Ok(logits[i * per..(i + 1) * per].to_vec())).collect()
                        }
                        Err(e) => (0..n)
                            .map(|_| Err(rans_sc::Error::runtime(format!("batch failed: {e}"))))
                            .collect(),
                    }
                })
            })
        };
        let wall = std::time::Instant::now();
        let n_clients = 4usize;
        let per_client = (n_requests / n_clients).max(4);
        let correct = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            for cidx in 0..n_clients {
                let batcher = batcher.clone();
                let set = &set;
                let correct = &correct;
                s.spawn(move || {
                    for i in 0..per_client {
                        let (xs, ys) = set.batch(cidx * per_client + i, 1);
                        let rx = batcher.submit(xs);
                        if let Ok((Ok(logits), _queue_ms)) = rx.recv() {
                            if argmax(&logits[0..classes]) == ys[0] as usize {
                                correct.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        let total = n_clients * per_client;
        let elapsed = wall.elapsed().as_secs_f64();
        println!(
            "{} concurrent requests: {:.1} req/s, accuracy {:.2}%",
            total,
            total as f64 / elapsed,
            correct.load(std::sync::atomic::Ordering::Relaxed) as f64 / total as f64 * 100.0
        );
        batcher.stop();
        worker.join().unwrap();
    }

    // ---- shutdown ----
    edge.shutdown_server()?;
    let _ = cloud_thread.join();
    println!("\ncloud metrics:\n{}", cloud.metrics().report());
    Ok(())
}
