//! Fig. 3 — encode/decode latency vs reshape dimension N.
//!
//! Paper shape: both curves flat (latency ≈ invariant in N) with small
//! error bars, because the pipeline is data-parallel in the symbol
//! count, not the row structure.
//!
//! Run: `cargo bench --bench fig3_latency_vs_n`

use rans_sc::eval::{feature_tensor, reshape_exp::latency_vs_n};

fn main() {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let (data, source) = feature_tensor(&dir, "resnet_mini_synth_a", 2).expect("fixture");
    println!("# Fig. 3 — enc/dec latency vs N (source {source:?})");
    let rows = latency_vs_n(&data, 4, 15).expect("fig3");
    println!("{:>10} {:>18} {:>18}", "N", "enc ms (mean±std)", "dec ms (mean±std)");
    let mut enc_means = Vec::new();
    for r in &rows {
        enc_means.push(r.enc.mean_ms());
        println!("{:>10} {:>18} {:>18}", r.n, r.enc.fmt_mean_std(), r.dec.fmt_mean_std());
    }
    let lo = enc_means.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = enc_means.iter().cloned().fold(0.0f64, f64::max);
    println!("# enc spread across N: {:.3}–{:.3} ms ({:.1}% variation)", lo, hi,
             if lo > 0.0 { (hi / lo - 1.0) * 100.0 } else { 0.0 });
}
