//! Table 4 — accuracy at split layers SL1–SL4 for Q ∈ {3, 4}.
//!
//! Paper shape: accuracy roughly stable (±1%) across split depth on
//! both datasets, trending slightly up with depth at Q=3.
//!
//! Requires artifacts. Run: `cargo bench --bench table4_split_layers`

use std::sync::Arc;

use rans_sc::data::VisionSet;
use rans_sc::eval::accuracy_sweep;
use rans_sc::runtime::{Engine, ExecPool, Manifest, VisionSplitExec};

fn main() {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let n: usize = std::env::var("RANS_SC_EVAL_N").ok().and_then(|s| s.parse().ok()).unwrap_or(200);
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            println!("# Table 4 skipped: {e}");
            return;
        }
    };
    let engine = Arc::new(Engine::cpu().expect("pjrt"));
    let pool = ExecPool::new(engine, dir.as_str());
    println!("# Table 4 — accuracy (%) by split layer, Q ∈ {{3,4}} ({n} samples/point)");
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>14}",
        "SL", "a: Q=3", "a: Q=4", "b: Q=3", "b: Q=4"
    );
    for sl in 1..=4usize {
        let mut cells = Vec::new();
        for ds in ["synth_a", "synth_b"] {
            let name = format!("resnet_mini_{ds}");
            let exec = VisionSplitExec::load(&pool, &manifest, &name, sl, 1).expect("exec");
            let set = VisionSet::load(manifest.resolve(&exec.entry.test_data)).expect("data");
            let pts = accuracy_sweep(&exec, &set, &[3, 4], n).expect("sweep");
            // pts[0] is baseline, then Q=3, Q=4.
            cells.push(pts[1].accuracy * 100.0);
            cells.push(pts[2].accuracy * 100.0);
        }
        println!(
            "{:>6} {:>14.2} {:>14.2} {:>14.2} {:>14.2}",
            format!("SL{sl}"),
            cells[0],
            cells[1],
            cells[2],
            cells[3]
        );
    }
}
