//! Table 2 — accuracy vs quantization bit-width Q ∈ {2..8}.
//!
//! ResNet-Mini at SL2 on both synthetic datasets (CIFAR100 / ImageNet
//! analogues). Paper shape: accuracy flat for Q ≥ 4, small dip at Q=3,
//! cliff at Q=2.
//!
//! Requires artifacts (`make artifacts`). Run:
//! `cargo bench --bench table2_accuracy_q`
//! Env: `RANS_SC_EVAL_N` samples per point (default 200).

use std::sync::Arc;

use rans_sc::data::VisionSet;
use rans_sc::eval::accuracy_sweep;
use rans_sc::runtime::{Engine, ExecPool, Manifest, VisionSplitExec};

fn main() {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let n: usize = std::env::var("RANS_SC_EVAL_N").ok().and_then(|s| s.parse().ok()).unwrap_or(200);
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            println!("# Table 2 skipped: {e}");
            return;
        }
    };
    let engine = Arc::new(Engine::cpu().expect("pjrt"));
    let pool = ExecPool::new(engine, dir.as_str());
    println!("# Table 2 — accuracy vs Q (ResNet-Mini, SL2, {n} samples/point)");
    println!("{:>6} {:>22} {:>22}", "Q", "synth_a (C100 analog)", "synth_b (IN analog)");
    let mut cols = Vec::new();
    for ds in ["synth_a", "synth_b"] {
        let name = format!("resnet_mini_{ds}");
        let exec = VisionSplitExec::load(&pool, &manifest, &name, 2, 1).expect("exec");
        let set = VisionSet::load(manifest.resolve(&exec.entry.test_data)).expect("data");
        let points = accuracy_sweep(&exec, &set, &[8, 7, 6, 5, 4, 3, 2], n).expect("sweep");
        cols.push(points);
    }
    // Baseline row then Q rows.
    let label = |q: Option<u8>| q.map(|v| v.to_string()).unwrap_or_else(|| "base".into());
    for i in 0..cols[0].len() {
        println!(
            "{:>6} {:>22.2} {:>22.2}",
            label(cols[0][i].q),
            cols[0][i].accuracy * 100.0,
            cols[1][i].accuracy * 100.0
        );
    }
}
