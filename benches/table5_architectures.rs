//! Table 5 — accuracy across diverse architectures at Q = 4.
//!
//! VGG / MobileNet / Swin / DenseNet / EfficientNet minis on the
//! ImageNet-analogue dataset, each at its exported split.
//!
//! Paper shape: |Δaccuracy| < ~0.2% of each architecture's baseline.
//!
//! Requires artifacts. Run: `cargo bench --bench table5_architectures`

use std::sync::Arc;

use rans_sc::data::VisionSet;
use rans_sc::eval::accuracy_sweep;
use rans_sc::runtime::{Engine, ExecPool, Manifest, VisionSplitExec};

fn main() {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let n: usize = std::env::var("RANS_SC_EVAL_N").ok().and_then(|s| s.parse().ok()).unwrap_or(200);
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            println!("# Table 5 skipped: {e}");
            return;
        }
    };
    let engine = Arc::new(Engine::cpu().expect("pjrt"));
    let pool = ExecPool::new(engine, dir.as_str());
    println!("# Table 5 — architecture sweep at Q=4 ({n} samples/model)");
    println!(
        "{:<24} {:>4} {:>12} {:>12} {:>10}",
        "Model", "SL", "Baseline %", "Ours %", "Δ"
    );
    let models = [
        "vgg_mini_synth_b",
        "mobilenet_mini_synth_b",
        "swin_mini_synth_b",
        "densenet_mini_synth_b",
        "efficientnet_mini_synth_b",
    ];
    for name in models {
        let entry = match manifest.vision_entry(name) {
            Ok(e) => e,
            Err(e) => {
                println!("{name:<24} skipped: {e}");
                continue;
            }
        };
        let sl = entry.splits[0].sl;
        let exec = VisionSplitExec::load(&pool, &manifest, name, sl, 1).expect("exec");
        let set = VisionSet::load(manifest.resolve(&exec.entry.test_data)).expect("data");
        let pts = accuracy_sweep(&exec, &set, &[4], n).expect("sweep");
        let base = pts[0].accuracy * 100.0;
        let ours = pts[1].accuracy * 100.0;
        println!(
            "{:<24} {:>4} {:>12.3} {:>12.3} {:>+10.3}",
            name, sl, base, ours, ours - base
        );
    }
}
