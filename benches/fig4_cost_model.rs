//! Fig. 4 — cost model T_tot(N) vs measured compressed size, Q ∈ {2,4,6,8}.
//!
//! Paper shape: the model curve tracks the measured curve; the curve is
//! U-shaped over the constrained domain; Algorithm 1's Ñ lands within
//! 2–3% of the exhaustive N* on compressed size.
//!
//! Run: `cargo bench --bench fig4_cost_model`

use rans_sc::eval::{cost_model_sweep, feature_tensor};

fn main() {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let (data, source) = feature_tensor(&dir, "resnet_mini_synth_a", 2).expect("fixture");
    println!("# Fig. 4 — T_tot(N) model vs measured size (source {source:?})");
    let sweeps = cost_model_sweep(&data, &[2, 4, 6, 8]).expect("fig4");
    for s in &sweeps {
        println!("\n## Q = {}", s.q);
        println!("{:>10} {:>16} {:>16}", "N", "model (KB)", "measured (KB)");
        for &(n, pred, actual) in &s.points {
            println!(
                "{:>10} {:>16.1} {:>16.1}",
                n,
                pred / 1000.0,
                actual as f64 / 1000.0
            );
        }
        println!(
            "# Ñ = {} ({:.1} KB) vs N* = {} ({:.1} KB): gap {:.2}% | evaluated {}/{} candidates",
            s.n_tilde,
            s.bytes_at_tilde as f64 / 1000.0,
            s.n_star,
            s.bytes_at_star as f64 / 1000.0,
            s.gap() * 100.0,
            s.evaluated,
            s.domain_size
        );
    }
}
