//! Hot-path microbenchmarks for the §Perf optimization pass.
//!
//! Reports raw throughput of each pipeline stage in isolation so
//! regressions localize: AIQ quantize, CSR encode/decode, frequency
//! table build, rANS encode/decode (per-lane and multi-lane), container
//! framing, and the end-to-end steady-state pipeline.
//!
//! Run: `cargo bench --bench perf_hotpath`

use rans_sc::eval::fixtures::synthetic_feature;
use rans_sc::pipeline::{self, PipelineConfig, ReshapeStrategy};
use rans_sc::quant::{quantize, QuantParams};
use rans_sc::rans::{decode, decode_interleaved, encode, encode_interleaved, FreqTable};
use rans_sc::reshape::{self, optimizer::OptimizerConfig};
use rans_sc::sparse::ModCsr;
use rans_sc::util::timer::measure;

fn mbps(bytes: usize, ms: f64) -> f64 {
    bytes as f64 / 1e6 / (ms / 1e3)
}

fn main() {
    let data = synthetic_feature(4242, 128, 28, 28, 0.35);
    let q = 4u8;
    let params = QuantParams::fit(q, &data).expect("fit");
    let symbols = quantize(&data, &params);
    let t = symbols.len();
    println!("# Perf hot-path microbenches (T = {t}, Q = {q})");

    let m = measure(3, 15, || quantize(&data, &params));
    println!(
        "quantize             {:>12}  ({:>8.1} MB/s over f32 input)",
        m.fmt_mean_std(),
        mbps(data.len() * 4, m.mean_ms())
    );

    let best = reshape::optimize(&symbols, params.zero_symbol(), &OptimizerConfig::paper(q))
        .expect("opt")
        .best;
    let (n, k) = (best.n, best.k);
    let m = measure(3, 15, || ModCsr::encode(&symbols, n, k, params.zero_symbol()).unwrap());
    println!(
        "csr encode           {:>12}  ({:>8.1} MB/s over u16 symbols)",
        m.fmt_mean_std(),
        mbps(t * 2, m.mean_ms())
    );

    let csr = ModCsr::encode(&symbols, n, k, params.zero_symbol()).unwrap();
    let m = measure(3, 15, || csr.decode().unwrap());
    println!("csr decode           {:>12}", m.fmt_mean_std());

    let d = csr.concat();
    let alphabet = csr.concat_alphabet(params.alphabet());
    let m = measure(3, 15, || FreqTable::from_symbols(&d, alphabet));
    println!("freq table build     {:>12}  ({} symbols)", m.fmt_mean_std(), d.len());

    let table = FreqTable::from_symbols(&d, alphabet);
    let m = measure(3, 15, || encode(&d, &table).unwrap());
    let stream = encode(&d, &table).unwrap();
    println!(
        "rANS encode 1-lane   {:>12}  ({:>8.1} Msym/s)",
        m.fmt_mean_std(),
        d.len() as f64 / 1e6 / (m.mean_ms() / 1e3)
    );
    let m = measure(3, 15, || decode(&stream, d.len(), &table).unwrap());
    println!(
        "rANS decode 1-lane   {:>12}  ({:>8.1} Msym/s)",
        m.fmt_mean_std(),
        d.len() as f64 / 1e6 / (m.mean_ms() / 1e3)
    );

    for lanes in [4usize, 8] {
        let m = measure(3, 15, || encode_interleaved(&d, &table, lanes, true).unwrap());
        let s = encode_interleaved(&d, &table, lanes, true).unwrap();
        let md = measure(3, 15, || decode_interleaved(&s, &table, true).unwrap());
        println!(
            "rANS enc/dec {lanes}-lane {:>12} / {:>12}",
            m.fmt_mean_std(),
            md.fmt_mean_std()
        );
    }

    let cfg = PipelineConfig {
        q,
        lanes: 8,
        parallel: rans_sc::pipeline::codec::default_parallelism(),
        reshape: ReshapeStrategy::Fixed(n),
    };
    let (bytes, _) = pipeline::compress_quantized(&symbols, params, &cfg).unwrap();
    let m = measure(3, 15, || pipeline::compress_quantized(&symbols, params, &cfg).unwrap());
    println!(
        "pipeline e2e encode  {:>12}  ({} B out, {:>8.1} MB/s in)",
        m.fmt_mean_std(),
        bytes.len(),
        mbps(data.len() * 4, m.mean_ms())
    );
    let m = measure(3, 15, || pipeline::decompress_to_symbols(&bytes, true).unwrap());
    println!("pipeline e2e decode  {:>12}", m.fmt_mean_std());

    let m = measure(1, 5, || {
        reshape::optimize(&symbols, params.zero_symbol(), &OptimizerConfig::paper(q)).unwrap()
    });
    println!("Algorithm 1 (cold)   {:>12}", m.fmt_mean_std());
}
