//! Hot-path microbenchmarks for the §Perf optimization pass.
//!
//! Reports raw throughput of each pipeline stage in isolation so
//! regressions localize: AIQ quantize, CSR encode/decode, frequency
//! table build, rANS encode/decode (per-lane, multi-state within one
//! lane, and multi-lane), container framing, the scoped-thread fan-out
//! baseline, and the persistent engine's pooled end-to-end path. Three
//! serving smokes ride in the same artifact: the session-layer
//! robustness soak, the registry verify/hot-swap churn, and the actor
//! daemon's 500-session synthetic-fleet run (req_per_s / p50 / p99).
//!
//! Run: `cargo bench --bench perf_hotpath`
//!
//! Env:
//! * `RANS_SC_BENCH_FAST=1` — reduced-iteration CI smoke mode
//!   (1 warmup / 3 trials instead of 3 / 15).
//! * `RANS_SC_BENCH_JSON=<path>` — also write the measurements as JSON
//!   (default `BENCH_perf_hotpath.json`; set to `0` to disable). CI
//!   uploads this artifact to record the perf trajectory over time.

use rans_sc::engine::{ContainerFormat, Engine, EngineConfig};
use rans_sc::eval::fixtures::synthetic_feature;
use rans_sc::pipeline::{self, PipelineConfig, ReshapeStrategy, StreamLayout};
use rans_sc::quant::{fit_and_quantize, quantize, QuantParams};
use rans_sc::rans::simd::{self, Backend};
use rans_sc::rans::{
    decode, decode_interleaved, decode_multistate, decode_multistate_scalar, encode,
    encode_interleaved, encode_multistate, FreqTable,
};
use rans_sc::reshape::{self, optimizer::OptimizerConfig};
use rans_sc::sparse::ModCsr;
use rans_sc::tensor::{narrow_to_half_bits, Dtype, TensorMut, TensorRef};
use rans_sc::util::json::{ObjBuilder, Value};
use rans_sc::util::timer::{measure, Measurement};

fn mbps(bytes: usize, ms: f64) -> f64 {
    bytes as f64 / 1e6 / (ms / 1e3)
}

/// Accumulates rows for both the stdout report and the JSON artifact.
/// Rows measured over a known symbol count also carry their throughput
/// in Msym/s — the unit the perf trajectory is tracked in.
struct Report {
    rows: Vec<(String, Measurement, Option<f64>)>,
    robustness: Option<RobustnessSmoke>,
    registry: Option<RegistrySmoke>,
    fleet: Option<rans_sc::coordinator::LoadReport>,
}

/// Outcome of the registry smoke: streaming verification throughput of
/// a multi-chunk artifact plus a hot-swap churn loop through the real
/// `ModelSlot` + `smoke_decode` machinery, so the registry's serving
/// cost rides in the same JSON artifact as the codec's.
struct RegistrySmoke {
    artifact_bytes: usize,
    verify_mbps: f64,
    swap_total: u64,
    rollback_total: u64,
    /// Two-version fleet sync: bytes a delta fetch of v2-given-v1 moves
    /// vs a cold full fetch of v2 (unique chunks, CDC-chunked).
    delta_bytes: usize,
    full_bytes: usize,
    delta_bytes_saved: usize,
    delta_shared_chunks: usize,
    delta_total_chunks: usize,
}

/// Outcome of the session-layer robustness smoke: a seeded soak over a
/// lossy in-proc link, driven through [`rans_sc::coordinator::Session`]
/// so the resilience counters in the JSON artifact reflect the real
/// retry/shed machinery rather than a simulation of it.
struct RobustnessSmoke {
    requests: usize,
    ok: usize,
    rejected: usize,
    retry_total: u64,
    shed_total: u64,
    reconnect_total: u64,
    wall_ms: f64,
}

impl Report {
    fn new() -> Self {
        Report { rows: Vec::new(), robustness: None, registry: None, fleet: None }
    }

    fn add(&mut self, name: &str, m: Measurement) -> &Measurement {
        self.rows.push((name.to_string(), m, None));
        &self.rows.last().unwrap().1
    }

    /// Add a row measured over `syms` symbols, recording Msym/s.
    fn add_syms(&mut self, name: &str, m: Measurement, syms: usize) -> &Measurement {
        let msym = syms as f64 / 1e6 / (m.mean_ms() / 1e3);
        self.rows.push((name.to_string(), m, Some(msym)));
        &self.rows.last().unwrap().1
    }

    fn msym_of(&self, name: &str) -> f64 {
        self.rows
            .iter()
            .find_map(|(n, _, msym)| if n == name { *msym } else { None })
            .unwrap_or(0.0)
    }

    fn to_json(
        &self,
        t: usize,
        q: u8,
        fast: bool,
        warmup: usize,
        trials: usize,
        simd_backends: (&str, &str, &str),
    ) -> Value {
        let rows: Vec<Value> = self
            .rows
            .iter()
            .map(|(name, m, msym)| {
                let mut row = ObjBuilder::new()
                    .field("name", name.as_str())
                    .field("mean_ms", m.mean_ms())
                    .field("std_ms", m.std_ms());
                if let Some(msym) = msym {
                    row = row.field("msym_per_s", *msym);
                }
                row.build()
            })
            .collect();
        let mut top = ObjBuilder::new()
            .field("bench", "perf_hotpath")
            .field("t", t)
            .field("q", q as usize)
            .field("fast", fast)
            .field("warmup", warmup)
            .field("trials", trials)
            // Headline scalar-core numbers, hoisted so the CI job
            // summary (and humans) can read them without walking rows.
            .field("scalar_encode_msym_s", self.msym_of("rans_encode_1lane"))
            .field("scalar_decode_msym_s", self.msym_of("rans_decode_1lane"))
            // Headline ILP number: 4-state interleaved decode, forced
            // scalar (v2 streams). CI bench-smoke fails if this key
            // goes missing.
            .field("multistate_decode_msym_s", self.msym_of("rans_decode_4state"))
            // Headline dtype-generic rows: fused bf16 compress (the
            // Llama2-style edge path — conversion-on-load quantize, no
            // intermediate f32 Vec) and zero-copy decompress_into a
            // reused caller buffer. CI bench-smoke fails if either key
            // goes missing.
            .field("bf16_compress_msym_s", self.msym_of("bf16_compress"))
            .field("decode_into_msym_s", self.msym_of("decode_into"))
            // Headline SIMD number: 4-state decode through the runtime
            // dispatcher (SSE4.1 on capable hosts, scalar elsewhere —
            // `simd_backend` records which; `simd8_backend` records the
            // 8-state row's path separately, since a host can have
            // SSE4.1 but not AVX2). CI bench-smoke fails if the
            // headline key goes missing and reports the simd/scalar
            // ratio.
            .field("simd_decode_msym_s", self.msym_of("rans_decode_simd4"))
            .field("simd_backend", simd_backends.0)
            .field("simd8_decode_msym_s", self.msym_of("rans_decode_simd8"))
            .field("simd8_backend", simd_backends.1)
            // Headline NEON numbers: 4-/8-state decode force-pinned to
            // the NEON backend. The keys are present on every ISA so
            // the bench-smoke schema never forks: on hosts without NEON
            // the rows are skipped, the throughputs report 0.0, and
            // `neon_backend` records "n/a" (CI checks presence, not
            // truthiness, for exactly this reason).
            .field("neon_decode_msym_s", self.msym_of("rans_decode_neon4"))
            .field("neon8_decode_msym_s", self.msym_of("rans_decode_neon8"))
            .field("neon_backend", simd_backends.2);
        // Session-layer robustness counters from the seeded lossy-link
        // soak. CI bench-smoke fails if `retry_total` / `shed_total` go
        // missing or report zero — a zero means the fault schedule (or
        // the retry machinery) silently stopped exercising the session.
        if let Some(s) = &self.robustness {
            top = top
                .field("retry_total", s.retry_total as usize)
                .field("shed_total", s.shed_total as usize)
                .field("reconnect_total", s.reconnect_total as usize)
                .field("soak_requests", s.requests)
                .field("soak_ok", s.ok)
                .field("soak_rejected", s.rejected)
                .field("soak_wall_ms", s.wall_ms);
        }
        // Registry verification + hot-swap counters. CI bench-smoke
        // fails if `registry_verify_mbps` or `swap_total` go missing or
        // report zero — a zero means the streaming verifier (or the
        // swap state machine) silently stopped being exercised.
        if let Some(r) = &self.registry {
            top = top
                .field("registry_verify_mbps", r.verify_mbps)
                .field("registry_artifact_bytes", r.artifact_bytes)
                .field("swap_total", r.swap_total as usize)
                .field("rollback_total", r.rollback_total as usize)
                // Delta-sync trajectory: CI bench-smoke fails if
                // `delta_bytes_saved` goes missing or reports zero — a
                // zero means two versions sharing almost all their
                // weights stopped deduplicating over the sync path.
                .field("delta_bytes", r.delta_bytes)
                .field("full_bytes", r.full_bytes)
                .field("delta_bytes_saved", r.delta_bytes_saved)
                .field("delta_shared_chunks", r.delta_shared_chunks)
                .field("delta_total_chunks", r.delta_total_chunks);
        }
        // Serving-daemon fleet smoke: a seeded synthetic fleet (hundreds
        // of chaos-linked edge sessions) through the actor daemon. CI
        // bench-smoke fails if `req_per_s` / `p50_ms` / `p99_ms` go
        // missing, and `fleet_unanswered` must read zero — anything else
        // means a request ended with no explicit outcome.
        if let Some(f) = &self.fleet {
            top = top
                .field("req_per_s", f.req_per_s)
                .field("p50_ms", f.p50_ms)
                .field("p99_ms", f.p99_ms)
                .field("fleet_edges", f.edges)
                .field("fleet_requests", f.requests as usize)
                .field("fleet_ok", f.ok as usize)
                .field("fleet_rejected", f.rejected as usize)
                .field("fleet_failed", f.failed as usize)
                .field("fleet_unanswered", f.unanswered)
                .field("fleet_dispatch_total", f.dispatch_total as usize)
                .field("fleet_batch_grow_total", f.batch_grow_total as usize)
                .field("fleet_batch_shrink_total", f.batch_shrink_total as usize)
                .field("fleet_max_batch", f.max_batch)
                .field("fleet_quota_shed_total", f.quota_shed_total as usize)
                .field("fleet_tenants", f.tenants_seen);
        }
        top.field("rows", rows).build()
    }
}

/// Drive a seeded burst of requests through a [`Session`] over a
/// dropping [`FaultyTransport`] whose responder sheds every seventh
/// request with `Busy`, and report the session's resilience counters.
fn robustness_smoke(fast: bool) -> RobustnessSmoke {
    use rans_sc::coordinator::{
        FaultSpec, FaultyTransport, Frame, FrameKind, Session, SessionConfig, Transport,
    };
    use rans_sc::telemetry::Registry;
    use std::sync::Arc;

    let requests = if fast { 200 } else { 500 };
    let spec = FaultSpec::drops(0.15);
    let (client, mut server) = FaultyTransport::pair(0xB0B0, spec, spec);
    let srv = std::thread::spawn(move || {
        let mut seen = 0u64;
        loop {
            let frame = match server.recv() {
                Ok(f) => f,
                Err(e) if e.to_string().contains("injected link fault") => continue,
                Err(_) => return, // peer closed
            };
            seen += 1;
            let kind = if seen % 7 == 0 {
                FrameKind::Busy { retry_after_ms: 1, message: "smoke shed".into() }
            } else {
                FrameKind::Pong
            };
            if server.send(&Frame::new(frame.request_id, kind)).is_err() {
                return;
            }
        }
    });
    let registry = Arc::new(Registry::new());
    let cfg = SessionConfig {
        deadline_ms: 2_000,
        try_timeout_ms: 40,
        max_retries: 10,
        base_backoff_ms: 1,
        max_backoff_ms: 4,
        heartbeat_ms: 0,
        seed: 0xB0B0,
    };
    let mut session = Session::new(client, cfg).with_metrics(Arc::clone(&registry));
    let sw = std::time::Instant::now();
    let (mut ok, mut rejected) = (0usize, 0usize);
    for _ in 0..requests {
        match session.call(FrameKind::Ping) {
            Ok(_) => ok += 1,
            Err(rans_sc::Error::Rejected { .. }) => rejected += 1,
            Err(_) => {}
        }
    }
    let wall_ms = sw.elapsed().as_secs_f64() * 1e3;
    drop(session);
    let _ = srv.join();
    RobustnessSmoke {
        requests,
        ok,
        rejected,
        retry_total: registry.get("session.retry_total"),
        shed_total: registry.get("session.shed_total"),
        reconnect_total: registry.get("session.reconnect_total"),
        wall_ms,
    }
}

/// Drive a seeded synthetic fleet through the actor serving daemon —
/// ≥500 concurrent edge sessions, a tenth of them on chaos links — and
/// return the loadgen's outcome accounting. The hard invariant is
/// `unanswered == 0`: the daemon must give every request an explicit
/// outcome even under link chaos, and the bench aborts if it doesn't.
fn fleet_smoke(fast: bool) -> rans_sc::coordinator::LoadReport {
    use rans_sc::coordinator::loadgen::{self, LoadgenConfig};

    let cfg = LoadgenConfig {
        edges: 500,
        requests_per_edge: if fast { 2 } else { 4 },
        tenants: 8,
        faulty_share: 0.1,
        service_us: if fast { 0 } else { 100 },
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(&cfg);
    assert_eq!(
        report.unanswered, 0,
        "fleet smoke: {} of {} requests ended without an explicit outcome",
        report.unanswered, report.requests
    );
    assert!(
        report.ok > 0,
        "fleet smoke: retrying sessions over mostly-clean links must land requests"
    );
    report
}

/// Publish a multi-chunk artifact to a scratch [`ChunkStore`] and time
/// the streaming verifier over it, then churn a versioned [`ModelSlot`]
/// through hot-swaps (including one deliberately failing candidate, so
/// the rollback path is exercised too).
fn registry_smoke(fast: bool, warmup: usize, trials: usize) -> RegistrySmoke {
    use rans_sc::runtime::registry::{
        smoke_decode, sync_deployment, CdcParams, ChunkStore, DeltaPlan, DeployParams,
        HmacSha256Signer, ModelSlot, RegistryManifest, StoreSource, SyncOptions,
    };

    let dir = std::env::temp_dir()
        .join(format!("rans_sc_bench_registry_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch registry dir");
    let store = ChunkStore::open(&dir);
    let n: usize = if fast { 4 << 20 } else { 16 << 20 };
    let mut rng = rans_sc::util::prng::Rng::new(0xBEEF);
    let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
    let desc = store.put_artifact(&bytes, 1 << 20).expect("publish artifact");
    let m = measure(warmup, trials, || store.verify_artifact(&desc).unwrap());
    let verify_mbps = mbps(n, m.mean_ms());

    let slot = ModelSlot::new(0u64, DeployParams::paper(4));
    let (mut swap_total, mut rollback_total) = (0u64, 0u64);
    let swaps = if fast { 4u64 } else { 8 };
    for v in 1..=swaps {
        slot.hot_swap(v, DeployParams::paper(4), smoke_decode).expect("hot swap");
        swap_total += 1;
    }
    // A stale candidate must roll back (version unchanged).
    if slot.hot_swap(swaps, DeployParams::paper(4), smoke_decode).is_err() {
        rollback_total += 1;
    }
    assert_eq!(slot.version(), swaps, "rollback left the active version");

    // Two-version fleet delta sync: v2 is v1 with an early 13-byte
    // insertion plus scattered single-byte edits — the fine-tune shape.
    // CDC chunking resynchronizes addresses past the insertion, so the
    // delta plan moves only the handful of touched chunks and the
    // bench records how much of a full fetch the fleet avoids.
    let signer = HmacSha256Signer::new(b"bench-fleet-key".as_slice(), "bench");
    let publisher = ChunkStore::open(dir.join("pub"));
    let head_n: usize = if fast { 2 << 20 } else { 8 << 20 };
    let mut rng = rans_sc::util::prng::Rng::new(0xDE17A);
    let head1: Vec<u8> = (0..head_n).map(|_| rng.next_u64() as u8).collect();
    let tail1: Vec<u8> = (0..head_n / 4).map(|_| rng.next_u64() as u8).collect();
    let mut head2 = Vec::with_capacity(head1.len() + 13);
    head2.extend_from_slice(&head1[..4096]);
    head2.extend_from_slice(&[0xA5; 13]);
    head2.extend_from_slice(&head1[4096..]);
    let step = head2.len() / 4;
    for i in (step..head2.len() - 1).step_by(step) {
        head2[i] ^= 0xFF;
    }
    let cdc = CdcParams::with_avg(1 << 14).expect("valid cdc params");
    let manifest = |v: u64, head: &[u8], tail: &[u8]| RegistryManifest {
        model: "fleet".into(),
        model_version: v,
        deploy: DeployParams::paper(4),
        head: publisher.put_artifact_cdc(head, &cdc).expect("cdc publish head"),
        tail: publisher.put_artifact_cdc(tail, &cdc).expect("cdc publish tail"),
    };
    let m1 = manifest(1, &head1, &tail1);
    publisher.publish(&m1, &signer).expect("publish v1");
    let m2 = manifest(2, &head2, &tail1);
    publisher.publish(&m2, &signer).expect("publish v2");
    let plan = DeltaPlan::plan(&m1, &m2);
    assert!(
        plan.shared_chunks * 10 >= plan.total_chunks * 9,
        "synthetic versions must share >=90% of chunks, got {}/{}",
        plan.shared_chunks,
        plan.total_chunks
    );
    assert!(
        plan.delta_bytes * 100 < plan.full_bytes * 15,
        "delta fetch must move <15% of full bytes, got {}/{}",
        plan.delta_bytes,
        plan.full_bytes
    );

    // Prove the plan against the real sync path: cold-sync v1 to a
    // fresh edge store, then delta-sync v2 — exactly the planned
    // missing bytes may cross the source boundary.
    let edge = ChunkStore::open(dir.join("edge"));
    let mut source = StoreSource::open(dir.join("pub"));
    sync_deployment(&edge, &mut source, &signer, "fleet", 1, &SyncOptions::default())
        .expect("cold sync v1");
    let (_, r2) =
        sync_deployment(&edge, &mut source, &signer, "fleet", 2, &SyncOptions::default())
            .expect("delta sync v2");
    assert_eq!(
        r2.bytes_fetched, plan.delta_bytes,
        "delta sync must move exactly the planned missing bytes"
    );

    let _ = std::fs::remove_dir_all(&dir);
    RegistrySmoke {
        artifact_bytes: n,
        verify_mbps,
        swap_total,
        rollback_total,
        delta_bytes: plan.delta_bytes as usize,
        full_bytes: plan.full_bytes as usize,
        delta_bytes_saved: plan.bytes_saved() as usize,
        delta_shared_chunks: plan.shared_chunks,
        delta_total_chunks: plan.total_chunks,
    }
}

fn main() {
    // "0" and empty disable fast mode, matching RANS_SC_BENCH_JSON's
    // convention; any other value enables it.
    let fast = std::env::var("RANS_SC_BENCH_FAST")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let (warmup, trials) = if fast { (1, 3) } else { (3, 15) };
    let mut report = Report::new();

    let data = synthetic_feature(4242, 128, 28, 28, 0.35);
    let q = 4u8;
    let params = QuantParams::fit(q, &data).expect("fit");
    let symbols = quantize(&data, &params);
    let t = symbols.len();
    println!("# Perf hot-path microbenches (T = {t}, Q = {q}, warmup {warmup}, trials {trials})");

    let m = report.add("quantize", measure(warmup, trials, || quantize(&data, &params)));
    println!(
        "quantize             {:>12}  ({:>8.1} MB/s over f32 input)",
        m.fmt_mean_std(),
        mbps(data.len() * 4, m.mean_ms())
    );

    // Fused fit+quantize: the float entry point's two-pass path
    // (min/max scan + divide-free quantize).
    let m = report.add(
        "fit_and_quantize",
        measure(warmup, trials, || fit_and_quantize(q, &data).unwrap()),
    );
    println!(
        "fit+quantize fused   {:>12}  ({:>8.1} MB/s over f32 input)",
        m.fmt_mean_std(),
        mbps(data.len() * 4, m.mean_ms())
    );

    // Dtype-generic zero-copy API: bf16 compress (conversion fused into
    // the quantize loads — the Llama2-style edge hot path) and
    // decompress_into a reused caller-owned bf16 buffer (no per-request
    // output allocation).
    let bf16_bits: Vec<u16> = narrow_to_half_bits(&data, Dtype::Bf16);
    let steady = Engine::new(EngineConfig::default());
    let bf16_cfg = PipelineConfig {
        q,
        lanes: 8,
        parallel: pipeline::codec::default_parallelism(),
        reshape: ReshapeStrategy::Optimize,
        layout: StreamLayout::V1,
    };
    let (bf16_bytes, bf16_stats) = steady
        .compress_tensor(TensorRef::from_bf16_bits(&bf16_bits), &bf16_cfg)
        .unwrap();
    let bf16_fixed = PipelineConfig {
        reshape: ReshapeStrategy::Fixed(bf16_stats.n_rows),
        ..bf16_cfg
    };
    let m = report.add_syms(
        "bf16_compress",
        measure(warmup, trials, || {
            steady
                .compress_tensor(TensorRef::from_bf16_bits(&bf16_bits), &bf16_fixed)
                .unwrap()
        }),
        bf16_bits.len(),
    );
    println!(
        "bf16 compress fused  {:>12}  ({} B out, {:>8.1} Msym/s)",
        m.fmt_mean_std(),
        bf16_bytes.len(),
        bf16_bits.len() as f64 / 1e6 / (m.mean_ms() / 1e3)
    );
    let mut bf16_out = vec![0u16; bf16_bits.len()];
    let m = report.add_syms(
        "decode_into",
        measure(warmup, trials, || {
            steady
                .decompress_into(&bf16_bytes, TensorMut::from_bf16_bits(&mut bf16_out))
                .unwrap()
        }),
        bf16_bits.len(),
    );
    println!(
        "decode_into bf16     {:>12}  ({:>8.1} Msym/s, caller buffer reused)",
        m.fmt_mean_std(),
        bf16_bits.len() as f64 / 1e6 / (m.mean_ms() / 1e3)
    );

    let best = reshape::optimize(&symbols, params.zero_symbol(), &OptimizerConfig::paper(q))
        .expect("opt")
        .best;
    let (n, k) = (best.n, best.k);
    let m = report.add(
        "csr_encode",
        measure(warmup, trials, || ModCsr::encode(&symbols, n, k, params.zero_symbol()).unwrap()),
    );
    println!(
        "csr encode           {:>12}  ({:>8.1} MB/s over u16 symbols)",
        m.fmt_mean_std(),
        mbps(t * 2, m.mean_ms())
    );

    let csr = ModCsr::encode(&symbols, n, k, params.zero_symbol()).unwrap();
    let m = report.add("csr_decode", measure(warmup, trials, || csr.decode().unwrap()));
    println!("csr decode           {:>12}", m.fmt_mean_std());

    let d = csr.concat();
    let alphabet = csr.concat_alphabet(params.alphabet());
    let m = report.add(
        "freq_table_build",
        measure(warmup, trials, || FreqTable::from_symbols(&d, alphabet)),
    );
    println!("freq table build     {:>12}  ({} symbols)", m.fmt_mean_std(), d.len());

    let table = FreqTable::from_symbols(&d, alphabet);
    // Warm the lazy division-free tables outside the timed region: the
    // steady-state serving path pays this once per frequency table, not
    // per call, so the row measures the inner loop alone.
    let _ = table.enc_table();
    let m = report.add_syms(
        "rans_encode_1lane",
        measure(warmup, trials, || encode(&d, &table).unwrap()),
        d.len(),
    );
    let stream = encode(&d, &table).unwrap();
    println!(
        "rANS encode 1-lane   {:>12}  ({:>8.1} Msym/s)",
        m.fmt_mean_std(),
        d.len() as f64 / 1e6 / (m.mean_ms() / 1e3)
    );
    let m = report.add_syms(
        "rans_decode_1lane",
        measure(warmup, trials, || decode(&stream, d.len(), &table).unwrap()),
        d.len(),
    );
    println!(
        "rANS decode 1-lane   {:>12}  ({:>8.1} Msym/s)",
        m.fmt_mean_std(),
        d.len() as f64 / 1e6 / (m.mean_ms() / 1e3)
    );

    // Intra-lane multi-state interleaving (v2 streams): same single
    // lane, N independent coder states round-robin over the symbols.
    // The decode rows are pinned to the *scalar* loop so they stay the
    // ILP baseline the SIMD rows below are measured against.
    for n in [2usize, 4, 8] {
        let m = report.add_syms(
            &format!("rans_encode_{n}state"),
            measure(warmup, trials, || encode_multistate(&d, &table, n).unwrap()),
            d.len(),
        );
        let ms_stream = encode_multistate(&d, &table, n).unwrap();
        println!(
            "rANS encode {n}-state  {:>12}  ({:>8.1} Msym/s)",
            m.fmt_mean_std(),
            d.len() as f64 / 1e6 / (m.mean_ms() / 1e3)
        );
        let m = report.add_syms(
            &format!("rans_decode_{n}state"),
            measure(warmup, trials, || {
                decode_multistate_scalar(&ms_stream, d.len(), &table, n).unwrap()
            }),
            d.len(),
        );
        println!(
            "rANS decode {n}-state  {:>12}  ({:>8.1} Msym/s, scalar)",
            m.fmt_mean_std(),
            d.len() as f64 / 1e6 / (m.mean_ms() / 1e3)
        );
    }

    // SIMD gather decode (runtime dispatch through the backend seam:
    // SSE4.1/AVX2 on x86_64, NEON on aarch64; falls back to the scalar
    // loop on hosts without them — the printed backend records which
    // path actually ran).
    for n in [4usize, 8] {
        let backend = simd::backend_for(n).expect("backend dispatch");
        let ms_stream = encode_multistate(&d, &table, n).unwrap();
        let m = report.add_syms(
            &format!("rans_decode_simd{n}"),
            measure(warmup, trials, || {
                decode_multistate(&ms_stream, d.len(), &table, n).unwrap()
            }),
            d.len(),
        );
        println!(
            "rANS decode simd {n}st {:>12}  ({:>8.1} Msym/s, {})",
            m.fmt_mean_std(),
            d.len() as f64 / 1e6 / (m.mean_ms() / 1e3),
            backend.name()
        );
    }
    let simd4_backend = simd::backend_for(4).expect("backend dispatch");
    let simd8_backend = simd::backend_for(8).expect("backend dispatch");
    if simd4_backend == Backend::Scalar {
        println!("# note: no 4-state SIMD on this host — simd4 row measured the scalar fallback");
    }
    if simd8_backend == Backend::Scalar {
        println!("# note: no 8-state SIMD on this host — simd8 row measured the scalar fallback");
    }

    // NEON rows, force-pinned through the backend seam where the host
    // has it (the aarch64 CI leg records real numbers). Elsewhere the
    // rows are skipped but the JSON headline keys stay present
    // (0.0 / "n/a"), keeping the bench-smoke schema ISA-independent.
    let neon_backend = if simd::backend_available(Backend::Neon) { "neon" } else { "n/a" };
    if simd::backend_available(Backend::Neon) {
        for n in [4usize, 8] {
            let ms_stream = encode_multistate(&d, &table, n).unwrap();
            let m = report.add_syms(
                &format!("rans_decode_neon{n}"),
                measure(warmup, trials, || {
                    simd::decode_multistate_with(&ms_stream, d.len(), &table, n, Backend::Neon)
                        .unwrap()
                }),
                d.len(),
            );
            println!(
                "rANS decode neon {n}st {:>12}  ({:>8.1} Msym/s, forced)",
                m.fmt_mean_std(),
                d.len() as f64 / 1e6 / (m.mean_ms() / 1e3)
            );
        }
    } else {
        println!("# note: no NEON on this host — neon rows reported n/a");
    }

    // Scoped-thread fan-out baseline: what the pre-engine hot path paid
    // per call. Compare with the pooled engine rows below.
    for lanes in [4usize, 8] {
        let m = measure(warmup, trials, || encode_interleaved(&d, &table, lanes, true).unwrap());
        let s = encode_interleaved(&d, &table, lanes, true).unwrap();
        let md = measure(warmup, trials, || decode_interleaved(&s, &table, true).unwrap());
        println!(
            "scoped enc/dec {lanes}-lane {:>10} / {:>12}",
            m.fmt_mean_std(),
            md.fmt_mean_std()
        );
        report.add_syms(&format!("scoped_encode_{lanes}lane"), m, d.len());
        report.add_syms(&format!("scoped_decode_{lanes}lane"), md, d.len());
    }

    let cfg = PipelineConfig {
        q,
        lanes: 8,
        parallel: pipeline::codec::default_parallelism(),
        reshape: ReshapeStrategy::Fixed(n),
        layout: StreamLayout::V1,
    };

    // Persistent engine, steady state: pooled workers + Fixed-N plan.
    let engine = Engine::new(EngineConfig::default());
    let (bytes, _) = engine.compress_quantized(&symbols, params, &cfg).unwrap();
    let m = report.add(
        "engine_e2e_encode",
        measure(warmup, trials, || engine.compress_quantized(&symbols, params, &cfg).unwrap()),
    );
    println!(
        "engine e2e encode    {:>12}  ({} B out, {:>8.1} MB/s in)",
        m.fmt_mean_std(),
        bytes.len(),
        mbps(data.len() * 4, m.mean_ms())
    );
    let m = report.add(
        "engine_e2e_decode",
        measure(warmup, trials, || engine.decompress_to_symbols(&bytes).unwrap()),
    );
    println!("engine e2e decode    {:>12}", m.fmt_mean_std());

    // Chunked v2: per-chunk framing + checksums.
    let engine_v2 = Engine::new(EngineConfig {
        format: ContainerFormat::ChunkedV2,
        ..EngineConfig::default()
    });
    let (bytes_v2, _) = engine_v2.compress_quantized(&symbols, params, &cfg).unwrap();
    let m = report.add(
        "engine_v2_encode",
        measure(warmup, trials, || engine_v2.compress_quantized(&symbols, params, &cfg).unwrap()),
    );
    println!(
        "engine v2 encode     {:>12}  ({} B out)",
        m.fmt_mean_std(),
        bytes_v2.len()
    );
    let m = report.add(
        "engine_v2_decode",
        measure(warmup, trials, || engine_v2.decompress_to_symbols(&bytes_v2).unwrap()),
    );
    println!("engine v2 decode     {:>12}", m.fmt_mean_std());

    // Library wrappers (shared engine) — the path user code takes.
    let m = report.add(
        "pipeline_e2e_encode",
        measure(warmup, trials, || pipeline::compress_quantized(&symbols, params, &cfg).unwrap()),
    );
    println!(
        "pipeline e2e encode  {:>12}  ({} B out, {:>8.1} MB/s in)",
        m.fmt_mean_std(),
        bytes.len(),
        mbps(data.len() * 4, m.mean_ms())
    );
    let m = report.add(
        "pipeline_e2e_decode",
        measure(warmup, trials, || pipeline::decompress_to_symbols(&bytes).unwrap()),
    );
    println!("pipeline e2e decode  {:>12}", m.fmt_mean_std());

    let m = report.add(
        "algorithm1_cold",
        measure(if fast { 0 } else { 1 }, if fast { 2 } else { 5 }, || {
            reshape::optimize(&symbols, params.zero_symbol(), &OptimizerConfig::paper(q)).unwrap()
        }),
    );
    println!("Algorithm 1 (cold)   {:>12}", m.fmt_mean_std());

    // Session-layer robustness smoke: same binary, same JSON artifact,
    // so the resilience trajectory rides next to the perf trajectory.
    let smoke = robustness_smoke(fast);
    println!(
        "robustness smoke     {} req over 15% lossy link: {} ok / {} rejected, \
         {} retries, {} sheds, {} reconnects ({:.0} ms)",
        smoke.requests,
        smoke.ok,
        smoke.rejected,
        smoke.retry_total,
        smoke.shed_total,
        smoke.reconnect_total,
        smoke.wall_ms
    );
    report.robustness = Some(smoke);

    // Registry smoke: streaming verification throughput + hot-swap
    // churn, feeding the registry_verify_mbps / swap_total JSON keys.
    let reg = registry_smoke(fast, warmup, trials);
    println!(
        "registry smoke       {:.0} MB verified at {:>8.1} MB/s, \
         {} swaps, {} rollback",
        reg.artifact_bytes as f64 / 1e6,
        reg.verify_mbps,
        reg.swap_total,
        reg.rollback_total
    );
    println!(
        "delta-sync smoke     v1->v2 shares {}/{} chunks: {} B delta vs {} B full \
         ({} B saved)",
        reg.delta_shared_chunks,
        reg.delta_total_chunks,
        reg.delta_bytes,
        reg.full_bytes,
        reg.delta_bytes_saved
    );
    report.registry = Some(reg);

    // Fleet smoke: the actor serving daemon under a synthetic fleet of
    // 500 chaos-linked edge sessions, feeding the req_per_s / p50_ms /
    // p99_ms JSON keys (and proving unanswered == 0 at scale).
    let fleet = fleet_smoke(fast);
    println!(
        "fleet smoke          {} edges x {} req: {} ok / {} rejected / {} failed, \
         0 unanswered; {:.0} req/s, p50 {:.2} ms, p99 {:.2} ms, \
         {} batches (max {:.0}), {} grow / {} shrink",
        fleet.edges,
        fleet.requests as usize / fleet.edges.max(1),
        fleet.ok,
        fleet.rejected,
        fleet.failed,
        fleet.req_per_s,
        fleet.p50_ms,
        fleet.p99_ms,
        fleet.dispatch_total,
        fleet.max_batch,
        fleet.batch_grow_total,
        fleet.batch_shrink_total
    );
    report.fleet = Some(fleet);

    // JSON artifact for the CI perf-trajectory record.
    let json_path =
        std::env::var("RANS_SC_BENCH_JSON").unwrap_or_else(|_| "BENCH_perf_hotpath.json".into());
    if json_path != "0" {
        let backends = (simd4_backend.name(), simd8_backend.name(), neon_backend);
        let json = report.to_json(t, q, fast, warmup, trials, backends).to_string_pretty();
        match std::fs::write(&json_path, &json) {
            Ok(()) => println!("# wrote {json_path}"),
            Err(e) => eprintln!("# could not write {json_path}: {e}"),
        }
    }
}
