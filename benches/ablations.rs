//! Ablations of the pipeline's design choices (DESIGN.md §ablations):
//!
//! 1. reshape: Optimize (Algorithm 1) vs Flat (N = T) vs worst-in-domain;
//! 2. modified (non-cumulative) vs standard (cumulative) CSR row array;
//! 3. rANS lane count scaling (1..16 lanes, serial vs threaded);
//! 4. Algorithm-1 patience (1 = paper early stop, larger = more search).
//!
//! Run: `cargo bench --bench ablations`

use rans_sc::eval::feature_tensor;
use rans_sc::pipeline::{self, PipelineConfig, ReshapeStrategy, StreamLayout};
use rans_sc::quant::{quantize, QuantParams};
use rans_sc::rans::{decode_interleaved, encode_interleaved, FreqTable};
use rans_sc::reshape::{self, optimizer::OptimizerConfig};
use rans_sc::sparse::ModCsr;
use rans_sc::util::stats;
use rans_sc::util::timer::measure;

fn main() {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let (data, source) = feature_tensor(&dir, "resnet_mini_synth_a", 2).expect("fixture");
    let q = 4u8;
    let params = QuantParams::fit(q, &data).expect("fit");
    let symbols = quantize(&data, &params);
    println!("# Ablations (source {source:?}, T = {}, Q = {q})", symbols.len());

    // 1. Reshape strategy.
    println!("\n## reshape strategy");
    for (label, strat) in [
        ("optimize (Alg.1)", ReshapeStrategy::Optimize),
        ("flat (N=T)", ReshapeStrategy::Flat),
    ] {
        let cfg = PipelineConfig {
            q,
            lanes: 8,
            parallel: true,
            reshape: strat,
            layout: StreamLayout::V1,
        };
        let (bytes, st) = pipeline::compress_quantized(&symbols, params, &cfg).expect("c");
        println!(
            "{label:<20} {:>10.1} KB  (N={}, K={}, H={:.3})",
            bytes.len() as f64 / 1000.0,
            st.n_rows,
            st.n_cols,
            st.entropy
        );
    }
    // Worst divisor in the constrained domain, for scale.
    {
        let ocfg = OptimizerConfig::paper(q);
        let oracle =
            reshape::exhaustive_search(&symbols, params.zero_symbol(), &ocfg, true).expect("ex");
        let worst = oracle
            .trace
            .iter()
            .max_by(|a, b| a.t_tot_bits.partial_cmp(&b.t_tot_bits).unwrap())
            .unwrap();
        let cfg = PipelineConfig {
            q,
            lanes: 8,
            parallel: true,
            reshape: ReshapeStrategy::Fixed(worst.n),
            layout: StreamLayout::V1,
        };
        let (bytes, _) = pipeline::compress_quantized(&symbols, params, &cfg).expect("c");
        println!(
            "{:<20} {:>10.1} KB  (N={})",
            "worst-in-domain",
            bytes.len() as f64 / 1000.0,
            worst.n
        );
    }

    // 2. Modified vs standard CSR row array entropy.
    println!("\n## row-count encoding (modified vs cumulative CSR)");
    {
        let ocfg = OptimizerConfig::paper(q);
        let best = reshape::optimize(&symbols, params.zero_symbol(), &ocfg).expect("opt").best;
        let csr = ModCsr::encode(&symbols, best.n, best.k, params.zero_symbol()).expect("csr");
        let direct = csr.row_counts.clone();
        let mut cumulative = Vec::with_capacity(direct.len());
        let mut acc = 0u32;
        for &c in &direct {
            acc += c;
            cumulative.push(acc);
        }
        for (label, arr) in [("non-cumulative r", &direct), ("cumulative r", &cumulative)] {
            let m = (*arr.iter().max().unwrap_or(&0) as usize) + 1;
            let freqs = stats::histogram(&arr.iter().map(|&x| x).collect::<Vec<u32>>(), m);
            println!(
                "{label:<20} alphabet {:>8}  entropy {:>7.3} b/sym  -> {:>8.1} B coded",
                m,
                stats::shannon_entropy(&freqs),
                stats::entropy_bits(&freqs) / 8.0
            );
        }
    }

    // 3. Lane scaling.
    println!("\n## rANS lane scaling (encode, steady state)");
    {
        let ocfg = OptimizerConfig::paper(q);
        let best = reshape::optimize(&symbols, params.zero_symbol(), &ocfg).expect("opt").best;
        let csr = ModCsr::encode(&symbols, best.n, best.k, params.zero_symbol()).expect("csr");
        let d = csr.concat();
        let table = FreqTable::from_symbols(&d, csr.concat_alphabet(params.alphabet()));
        for lanes in [1usize, 2, 4, 8, 16] {
            for parallel in [false, true] {
                let enc = measure(2, 10, || {
                    encode_interleaved(&d, &table, lanes, parallel).expect("enc")
                });
                let bytes = encode_interleaved(&d, &table, lanes, parallel).expect("enc");
                let dec = measure(2, 10, || {
                    decode_interleaved(&bytes, &table, parallel).expect("dec")
                });
                println!(
                    "lanes {lanes:>2} {} enc {:>10} dec {:>10} ({} B)",
                    if parallel { "par" } else { "ser" },
                    enc.fmt_mean_std(),
                    dec.fmt_mean_std(),
                    bytes.len()
                );
            }
        }
    }

    // 4. Patience.
    println!("\n## Algorithm-1 patience");
    for patience in [1usize, 2, 4, 8] {
        let mut cfg = OptimizerConfig::paper(q);
        cfg.patience = patience;
        let out = reshape::optimize(&symbols, params.zero_symbol(), &cfg).expect("opt");
        println!(
            "patience {patience}: evaluated {:>4}/{:<4} candidates, best N = {:>6}, T_tot = {:.0} bits",
            out.evaluated, out.domain_size, out.best.n, out.best.t_tot_bits
        );
    }
}
