//! Fig. 2 — reshape configurations vs symbol-distribution skew.
//!
//! Reproduces the paper's ladder on X ∈ R^{128×28×28}: reshapes to
//! K ∈ {128, 56, 16, 7}, reporting the entropy of D = v⊕c⊕r, the
//! compressed size, and a coarse histogram sketch per configuration.
//!
//! Paper shape: entropy falls (6.348 → 3.989 in the paper's example) and
//! compressed size falls as K shrinks toward the constrained domain.
//!
//! Run: `cargo bench --bench fig2_reshape_hist`

use rans_sc::eval::{feature_tensor, reshape_exp::reshape_histogram};

fn sketch(hist: &[u64], width: usize) -> String {
    let max = hist.iter().copied().max().unwrap_or(1).max(1);
    let bins = width.min(hist.len());
    let per = hist.len().div_ceil(bins);
    let mut out = String::new();
    for b in 0..bins {
        let v: u64 = hist[b * per..((b + 1) * per).min(hist.len())].iter().sum();
        let level = (v as f64 / max as f64 * 8.0).round() as usize;
        out.push(['.', ':', '-', '=', '+', '*', '#', '%', '@'][level.min(8)]);
    }
    out
}

fn main() {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let (data, source) = feature_tensor(&dir, "resnet_mini_synth_a", 2).expect("fixture");
    let t = data.len();
    println!("# Fig. 2 — reshape vs entropy/size (T = {t}, source {source:?})");
    // The paper's K ladder, kept to divisors of T.
    let ks = [128usize, 56, 16, 7];
    let ns: Vec<usize> = ks
        .iter()
        .filter(|&&k| t % k == 0)
        .map(|&k| t / k)
        .collect();
    let rows = reshape_histogram(&data, 4, &ns).expect("fig2");
    println!(
        "{:>10} {:>8} {:>12} {:>14}  histogram(D)",
        "N", "K", "entropy b/s", "size (KB)"
    );
    for r in &rows {
        println!(
            "{:>10} {:>8} {:>12.3} {:>14.1}  |{}|",
            r.n,
            r.k,
            r.entropy,
            r.compressed_bytes as f64 / 1000.0,
            sketch(&r.histogram, 32)
        );
    }
    if rows.len() >= 2 {
        let first = &rows[0];
        let last = &rows[rows.len() - 1];
        println!(
            "# entropy {:.3} -> {:.3}; size {:.1} KB -> {:.1} KB",
            first.entropy,
            last.entropy,
            first.compressed_bytes as f64 / 1000.0,
            last.compressed_bytes as f64 / 1000.0
        );
    }
}
