//! Table 1 — codec comparison: data size, encode time, decode time.
//!
//! Rows: E-1 binary serialization, E-2 tANS, E-3 DietGPU-style, plus
//! lz77/byte-rans comparators and Ours at Q ∈ {3, 4, 6}.
//!
//! Paper shape to reproduce: Ours < E-3 < E-2 < E-1 on size (7.2× vs
//! E-1, 2.8× vs E-3 at Q=3); tANS encode ~3 orders of magnitude slower;
//! ours sub-millisecond both directions.
//!
//! Run: `cargo bench --bench table1_codecs`
//! Env: `RANS_SC_ARTIFACTS` (default `artifacts`) — uses the real
//! ResNet-Mini SL2 IF when available, synthetic stand-in otherwise.

use rans_sc::eval::{codec_comparison, feature_tensor};

fn main() {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let (data, source) =
        feature_tensor(&dir, "resnet_mini_synth_a", 2).expect("fixture");
    println!("# Table 1 — codec comparison");
    println!("# feature: {} f32 ({} KB raw), source {source:?}", data.len(), data.len() * 4 / 1000);
    let rows = codec_comparison(&data, &[3, 4, 6], 2, 10).expect("comparison");
    println!("{:<20} {:>12} {:>16} {:>16}", "Method", "Size (KB)", "Enc (ms)", "Dec (ms)");
    for r in &rows {
        println!(
            "{:<20} {:>12.1} {:>16} {:>16}",
            r.name,
            r.size_kb(),
            r.enc.fmt_mean_std(),
            r.dec.fmt_mean_std()
        );
    }
    let binary = rows.iter().find(|r| r.name.contains("E-1")).unwrap();
    let diet = rows.iter().find(|r| r.name.contains("E-3")).unwrap();
    if let Some(ours) = rows.iter().find(|r| r.name.contains("Q=3")) {
        println!(
            "# ours(Q=3) vs E-1: {:.1}x smaller | vs E-3: {:.1}x smaller",
            binary.size_bytes as f64 / ours.size_bytes as f64,
            diet.size_bytes as f64 / ours.size_bytes as f64
        );
    }
}
