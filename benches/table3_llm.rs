//! Table 3 — Llama-Mini (s/m) across seven MC task suites.
//!
//! Columns per (model, task): accuracy, T_comm(Ñ) under the ε-outage
//! channel, payload size, enc/dec ms — baseline row plus Q ∈ {2,4,6,8}.
//!
//! Paper shape: T_comm reduction 2.2–4.3× (ratio grows as Q falls);
//! accuracy ≈ baseline at Q ∈ {6,8}, degraded at Q=2; enc/dec ≈
//! constant across tasks/Q.
//!
//! Requires artifacts. Run: `cargo bench --bench table3_llm`
//! Env: `RANS_SC_EVAL_N` items per task (default 24);
//! `RANS_SC_EVAL_DTYPE` wire dtype for the features (`f32` default,
//! `bf16` for the Llama2-style half-precision path, `f16`).

use std::sync::Arc;

use rans_sc::channel::OutageChannel;
use rans_sc::data::McTask;
use rans_sc::eval::lm_task_sweep;
use rans_sc::runtime::{Engine, ExecPool, LmSplitExec, Manifest};
use rans_sc::tensor::Dtype;

fn main() {
    let dir = std::env::var("RANS_SC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let n: usize = std::env::var("RANS_SC_EVAL_N").ok().and_then(|s| s.parse().ok()).unwrap_or(24);
    let dtype = std::env::var("RANS_SC_EVAL_DTYPE")
        .ok()
        .map(|s| Dtype::parse(&s).expect("RANS_SC_EVAL_DTYPE"))
        .unwrap_or(Dtype::F32);
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            println!("# Table 3 skipped: {e}");
            return;
        }
    };
    let engine = Arc::new(Engine::cpu().expect("pjrt"));
    let pool = ExecPool::new(engine, dir.as_str());
    let channel = OutageChannel::paper_default();
    println!(
        "# Table 3 — Llama-Mini MC sweep ({n} items/task, {dtype} features, ε-outage T_comm)"
    );

    for lm in &manifest.lm {
        let exec = LmSplitExec::load(&pool, &manifest, &lm.name).expect("lm exec");
        println!("\n## {} (dim {}, split {})", lm.name, lm.dim, lm.split);
        println!(
            "{:<12} {:>6} {:>8} {:>12} {:>12} {:>12} {:>12}",
            "task", "Q", "acc %", "T_comm ms", "size KB", "enc ms", "dec ms"
        );
        for tf in &lm.tasks {
            let task = McTask::load(manifest.resolve(&tf.path)).expect("task bin");
            let rows = lm_task_sweep(&exec, &task, &tf.name, &[2, 4, 6, 8], n, &channel, dtype)
                .expect("sweep");
            let base_t = rows[0].t_comm_ms;
            for r in &rows {
                let q = r.q.map(|v| v.to_string()).unwrap_or_else(|| "base".into());
                let speedup = if r.q.is_some() && r.t_comm_ms > 0.0 {
                    format!(" ({:.2}x)", base_t / r.t_comm_ms)
                } else {
                    String::new()
                };
                println!(
                    "{:<12} {:>6} {:>8.2} {:>12} {:>12.1} {:>12} {:>12}",
                    r.task,
                    q,
                    r.accuracy * 100.0,
                    format!("{:.2}{speedup}", r.t_comm_ms),
                    r.mean_payload_bytes / 1000.0,
                    format!("{:.2}({:.2})", r.enc_ms.mean(), r.enc_ms.std()),
                    format!("{:.2}({:.2})", r.dec_ms.mean(), r.dec_ms.std()),
                );
            }
        }
    }
}
