"""Synthetic dataset generators + binary formats."""

import os
import struct

import numpy as np
import pytest

from compile import data as D


def test_vision_dataset_deterministic_and_learnable_structure():
    spec = D.VISION_SPECS["synth_a"]
    x1, y1, xt1, yt1 = D.make_vision_dataset(spec, 64, 32)
    x2, y2, _, _ = D.make_vision_dataset(spec, 64, 32)
    assert np.array_equal(x1, x2)
    assert np.array_equal(y1, y2)
    assert x1.shape == (64, D.IMG_H, D.IMG_W, D.IMG_C)
    assert y1.min() >= 0 and y1.max() < spec.num_classes
    # Same-class samples are (on average) more correlated than
    # cross-class ones; average over pairs to keep this statistical.
    same_corrs, diff_corrs = [], []
    for i in range(16):
        for j in range(i + 1, 16):
            c = np.corrcoef(x1[i].ravel(), x1[j].ravel())[0, 1]
            (same_corrs if y1[i] == y1[j] else diff_corrs).append(c)
    if same_corrs and diff_corrs:
        assert np.mean(same_corrs) > np.mean(diff_corrs)


def test_vision_bin_roundtrip(tmp_path):
    spec = D.VISION_SPECS["synth_b"]
    _, _, x, y = D.make_vision_dataset(spec, 8, 16)
    path = str(tmp_path / "v.bin")
    D.write_vision_bin(path, x, y, spec.num_classes)
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:4] == b"RSCD"
    ver, n, h, w, c, nc = struct.unpack_from("<6I", buf, 4)
    assert (ver, n, h, w, c, nc) == (1, 16, 32, 32, 3, spec.num_classes)
    labels = np.frombuffer(buf, "<u4", count=n, offset=28)
    assert np.array_equal(labels, y.astype(np.uint32))
    imgs = np.frombuffer(buf, "<f4", offset=28 + 4 * n).reshape(n, h, w, c)
    assert np.allclose(imgs, x)


@pytest.mark.parametrize("task", D.LM_TASKS)
def test_mc_items_well_formed(task):
    rng = np.random.default_rng(0)
    for _ in range(20):
        choices, starts, lens, correct = D.gen_mc_item(task, rng)
        assert choices.shape == (D.N_CHOICES, D.SEQ_LEN)
        assert 0 <= correct < D.N_CHOICES
        assert choices.min() >= 0 and choices.max() < D.VOCAB
        # Distractors differ from the correct answer span.
        s, ln = starts[correct], lens[correct]
        gold = tuple(choices[correct, s : s + ln])
        for i in range(D.N_CHOICES):
            if i != correct:
                si, li = starts[i], lens[i]
                assert tuple(choices[i, si : si + li]) != gold


@pytest.mark.parametrize("task", D.LM_TASKS)
def test_mc_task_is_solvable_by_rule(task):
    """The generating rule itself must disambiguate the correct answer —
    otherwise the LM benchmark measures noise."""
    rng = np.random.default_rng(1)
    for _ in range(10):
        ctx, ans = D._gen_item(task, rng)
        ctx2, ans2 = D._gen_item(task, rng)
        # Regenerating with the same context is not exposed; instead check
        # answers are deterministic functions: same (task, ctx) built in
        # _gen_item yields a unique ans by construction. Sanity: answer
        # tokens are in-vocab and of ANS_LEN.
        assert len(ans) == D.ANS_LEN
        assert all(0 <= t < D.VOCAB for t in ans)


def test_training_corpus_mix_and_shape():
    corpus = D.gen_training_corpus(70, seed=3)
    assert corpus.shape == (70, D.SEQ_LEN)
    assert corpus.min() >= 0 and corpus.max() < D.VOCAB
    # Every sequence has a SEP delimiter.
    assert (corpus == D.SEP).any(axis=1).all()


def test_mc_task_bin_roundtrip(tmp_path):
    path = str(tmp_path / "t.bin")
    D.write_mc_task_bin(path, "retrieval", 5, seed=7)
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:4] == b"RSCT"
    ver, n, c, t, v = struct.unpack_from("<5I", buf, 4)
    assert (ver, n, c, t, v) == (1, 5, D.N_CHOICES, D.SEQ_LEN, D.VOCAB)
    # Walk one item to validate framing.
    pos = 24
    (correct,) = struct.unpack_from("<I", buf, pos)
    pos += 4
    assert correct < c
    s, ln = struct.unpack_from("<2I", buf, pos)
    assert 0 < s and s + ln <= t
    # File ends exactly at the expected size.
    expected = 24 + n * (4 + c * (8 + 4 * t))
    assert len(buf) == expected
