"""Layer-1 Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, value ranges and bit-widths; every kernel must
match its `ref.py` oracle exactly (integer outputs) or to float
tolerance (dequantize).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    aiq_dequantize,
    aiq_quantize,
    minmax,
    row_nonzero_counts,
    symbol_histogram,
)
from compile.kernels.quantize import quantize_with_params
from compile.kernels import ref

import os

SETTINGS = dict(
    max_examples=int(os.environ.get("RANS_SC_HYP_EXAMPLES", "25")), deadline=None
)


def tensor_strategy(max_elems=6000):
    """Random-shaped float tensors incl. negative ranges and sparsity."""

    @st.composite
    def _build(draw):
        ndim = draw(st.integers(1, 3))
        dims = [draw(st.integers(1, 24)) for _ in range(ndim)]
        while int(np.prod(dims)) > max_elems:
            dims[dims.index(max(dims))] //= 2
            dims = [max(1, d) for d in dims]
        seed = draw(st.integers(0, 2**31 - 1))
        sparsity = draw(st.floats(0.0, 0.9))
        scale = draw(st.sampled_from([0.01, 1.0, 100.0]))
        shift = draw(st.sampled_from([-5.0, 0.0, 3.0]))
        rng = np.random.default_rng(seed)
        x = rng.normal(size=dims).astype(np.float32) * scale + shift
        mask = rng.random(size=dims) < sparsity
        x[mask] = 0.0
        return jnp.asarray(x)

    return _build()


@given(x=tensor_strategy())
@settings(**SETTINGS)
def test_minmax_matches_ref(x):
    mn, mx = minmax(x)
    rmn, rmx = ref.minmax_ref(x)
    assert np.allclose(mn, rmn)
    assert np.allclose(mx, rmx)


@given(x=tensor_strategy(), q=st.sampled_from([2, 3, 4, 6, 8]))
@settings(**SETTINGS)
def test_quantize_matches_ref(x, q):
    levels = jnp.float32(2**q - 1)
    mn, mx = ref.minmax_ref(x)
    scale, zero = ref.aiq_params_ref(mn, mx, levels)
    got = aiq_quantize(x, scale, zero, levels)
    want = ref.aiq_quantize_ref(x, scale, zero, levels)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert int(jnp.min(got)) >= 0
    assert int(jnp.max(got)) <= 2**q - 1


@given(x=tensor_strategy(), q=st.sampled_from([2, 4, 8]))
@settings(**SETTINGS)
def test_dequantize_matches_ref_and_bounds_error(x, q):
    levels = jnp.float32(2**q - 1)
    sym, scale, zero = quantize_with_params(x, levels)
    got = aiq_dequantize(sym, scale, zero)
    want = ref.aiq_dequantize_ref(sym, scale, zero)
    assert np.allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)
    # Reconstruction error bounded by one quantization step — except for
    # degenerate ranges (x_max == x_min), where scale falls back to 1 and
    # constants far from 0 are clamped (Eq. 6 has no information to
    # reconstruct them; heads never emit such tensors, see ref.py).
    mn, mx = ref.minmax_ref(x)
    if float(mx) > float(mn):
        err = np.abs(np.asarray(got) - np.asarray(x))
        assert err.max() <= float(scale) * 1.0 + 1e-5


@given(x=tensor_strategy(), q=st.sampled_from([2, 4, 8]))
@settings(**SETTINGS)
def test_fused_epilogue_consistent(x, q):
    levels = jnp.float32(2**q - 1)
    sym, scale, zero = quantize_with_params(x, levels)
    mn, mx = ref.minmax_ref(x)
    rscale, rzero = ref.aiq_params_ref(mn, mx, levels)
    assert np.allclose(scale, rscale)
    assert np.allclose(zero, rzero)
    want = ref.aiq_quantize_ref(x, rscale, rzero, levels)
    assert np.array_equal(np.asarray(sym), np.asarray(want))


@given(
    n=st.integers(1, 80),
    k=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
    bg=st.integers(0, 15),
)
@settings(**SETTINGS)
def test_rowcount_matches_ref(n, k, seed, bg):
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.integers(0, 16, size=(n, k)), jnp.int32)
    got = row_nonzero_counts(m, jnp.int32(bg))
    want = ref.row_nonzero_counts_ref(m, jnp.int32(bg))
    assert np.array_equal(np.asarray(got), np.asarray(want))


@given(
    length=st.integers(0, 5000),
    alphabet=st.sampled_from([2, 16, 64, 257]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_histogram_matches_ref(length, alphabet, seed):
    rng = np.random.default_rng(seed)
    sym = jnp.asarray(rng.integers(0, alphabet, size=(length,)), jnp.int32)
    got = symbol_histogram(sym, alphabet)
    want = ref.symbol_histogram_ref(sym, alphabet)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert int(jnp.sum(got)) == length


def test_quantize_zero_maps_to_zero_roundtrip():
    """Post-ReLU zeros must reconstruct exactly (sparsity preservation)."""
    x = jnp.asarray([0.0, 0.5, 1.25, 0.0, 3.0], jnp.float32)
    for q in (2, 4, 8):
        levels = jnp.float32(2**q - 1)
        sym, scale, zero = quantize_with_params(x, levels)
        back = aiq_dequantize(sym, scale, zero)
        assert float(back[0]) == 0.0
        assert float(back[3]) == 0.0


def test_constant_tensor_degenerate_range():
    x = jnp.full((100,), 2.5, jnp.float32)
    sym, scale, zero = quantize_with_params(x, jnp.float32(15.0))
    assert float(scale) == 1.0  # degenerate-range fallback
    # All symbols identical.
    assert int(jnp.min(sym)) == int(jnp.max(sym))


def test_kernels_lower_to_hlo_text():
    """The interpret-mode kernels must survive the AOT export path."""
    from compile.hlo import to_hlo_text

    def fn(x, levels):
        sym, scale, zero = quantize_with_params(x, levels)
        return (sym, scale, zero)

    spec = jax.ShapeDtypeStruct((4, 8, 8), jnp.float32)
    lv = jax.ShapeDtypeStruct((), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, lv))
    assert "HloModule" in text
    assert len(text) > 1000
