"""Training-loop smoke tests (tiny budgets)."""

import jax
import numpy as np

from compile import data as D
from compile import train as T
from compile.models import resnet, common, llama_mini


def test_adam_reduces_quadratic():
    import jax.numpy as jnp

    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = T.adam_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, opt = T.adam_step(params, grads, opt, lr=0.1)
    assert float(loss(params)) < 1e-2


def test_vision_training_improves_over_chance():
    spec = D.VISION_SPECS["synth_a"]
    x_tr, y_tr, x_te, y_te = D.make_vision_dataset(spec, 256, 96)
    params = T.train_vision(
        resnet, spec.num_classes, x_tr, y_tr, steps=30, batch=32, lr=3e-3, log=None
    )
    acc = T.eval_vision(resnet, params, x_te, y_te)
    assert acc > 2.0 / spec.num_classes, f"accuracy {acc} at chance"


def test_lm_training_reduces_loss():
    # Two snapshots of the same loop: later loss < earlier loss.
    losses = []

    def capture(msg):
        if "loss" in msg:
            losses.append(float(msg.rsplit("loss", 1)[1]))

    T.train_lm("s", steps=30, batch=16, lr=2e-3, corpus_size=256, log=capture)
    assert len(losses) >= 2
    assert losses[-1] < losses[0], losses


def test_params_cache_roundtrip(tmp_path):
    params = resnet.init(jax.random.PRNGKey(0), 10)
    path = str(tmp_path / "p.npz")
    T.save_params(path, params)
    like = resnet.init(jax.random.PRNGKey(1), 10)
    loaded = T.load_params(path, like)
    assert loaded is not None
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 32, 3))
    y0 = common.forward(resnet, params, x)
    y1 = common.forward(resnet, loaded, x)
    assert np.array_equal(np.asarray(y0), np.asarray(y1))
    # Mismatched structure falls back to None (forces retrain).
    like20 = resnet.init(jax.random.PRNGKey(1), 20)
    assert T.load_params(path, like20) is None


def test_eval_lm_mc_runs():
    params = llama_mini.init(jax.random.PRNGKey(3), "s")
    acc = T.eval_lm_mc(params, "s", "majority", n_items=4, seed=0)
    assert 0.0 <= acc <= 1.0
