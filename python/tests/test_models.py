"""Model-zoo structural tests: split consistency, shapes, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.layers import count_params
from compile.models import VISION_MODELS, common, llama_mini


@pytest.fixture(scope="module")
def img_batch():
    return jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32, 3))


@pytest.mark.parametrize("name", sorted(VISION_MODELS))
def test_split_equals_full(name, img_batch):
    """head(sl) ∘ tail(sl) must reproduce the full forward for every SL."""
    model = VISION_MODELS[name]
    params = model.init(jax.random.PRNGKey(1), 10)
    full = common.forward(model, params, img_batch)
    assert full.shape == (2, 10)
    for sl in model.SPLITS:
        feat = common.head_apply(model, params, img_batch, sl)
        logits = common.tail_apply(model, params, feat, sl)
        assert np.allclose(np.asarray(full), np.asarray(logits), atol=1e-4), f"SL{sl}"


@pytest.mark.parametrize("name", sorted(VISION_MODELS))
def test_feature_shapes_shrink_spatially(name, img_batch):
    model = VISION_MODELS[name]
    params = model.init(jax.random.PRNGKey(2), 10)
    sizes = []
    for sl in model.SPLITS:
        feat = common.head_apply(model, params, img_batch, sl)
        assert feat.ndim == 4  # NHWC at every split boundary
        sizes.append(feat.shape[1] * feat.shape[2])
    assert sizes == sorted(sizes, reverse=True) or len(set(sizes)) > 1 or True
    # Spatial size never grows with depth.
    for a, b in zip(sizes, sizes[1:]):
        assert b <= a


@pytest.mark.parametrize("name", sorted(VISION_MODELS))
def test_deterministic_init_and_forward(name, img_batch):
    model = VISION_MODELS[name]
    p1 = model.init(jax.random.PRNGKey(3), 10)
    p2 = model.init(jax.random.PRNGKey(3), 10)
    y1 = common.forward(model, p1, img_batch)
    y2 = common.forward(model, p2, img_batch)
    assert np.array_equal(np.asarray(y1), np.asarray(y2))


@pytest.mark.parametrize("name", sorted(VISION_MODELS))
def test_param_counts_mini_scale(name):
    model = VISION_MODELS[name]
    params = model.init(jax.random.PRNGKey(4), 100)
    n = count_params(params)
    assert 10_000 < n < 5_000_000, f"{name}: {n} params out of mini-scale range"


@pytest.mark.parametrize("size", ["s", "m"])
def test_llama_split_equals_full(size):
    params = llama_mini.init(jax.random.PRNGKey(5), size)
    toks = jax.random.randint(jax.random.PRNGKey(6), (4, llama_mini.SEQ_LEN), 0, llama_mini.VOCAB)
    full = llama_mini.forward(params, toks, size)
    sl = llama_mini.default_split(size)
    hidden = llama_mini.head_apply(params, toks, size, sl)
    logits = llama_mini.tail_apply(params, hidden, size, sl)
    assert np.allclose(np.asarray(full), np.asarray(logits), atol=1e-4)
    assert full.shape == (4, llama_mini.SEQ_LEN, llama_mini.VOCAB)


def test_llama_causality():
    """Changing a future token must not affect earlier logits."""
    params = llama_mini.init(jax.random.PRNGKey(7), "s")
    toks = jax.random.randint(jax.random.PRNGKey(8), (1, llama_mini.SEQ_LEN), 8, llama_mini.VOCAB)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % llama_mini.VOCAB)
    l1 = llama_mini.forward(params, toks, "s")
    l2 = llama_mini.forward(params, toks2, "s")
    assert np.allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]), atol=1e-5)


def test_llama_sizes_ordered():
    ps = llama_mini.init(jax.random.PRNGKey(9), "s")
    pm = llama_mini.init(jax.random.PRNGKey(9), "m")
    assert count_params(pm) > count_params(ps) * 2
