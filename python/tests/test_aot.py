"""AOT export path: HLO text generation and artifact wiring."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.hlo import export_fn, to_hlo_text
from compile.kernels.dequantize import aiq_dequantize
from compile.kernels.quantize import quantize_with_params
from compile.models import resnet, common


def test_export_simple_fn(tmp_path):
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    path = str(tmp_path / "fn.hlo.txt")
    text = export_fn(fn, (spec, spec), path)
    assert os.path.exists(path)
    assert text.startswith("HloModule")
    assert "f32[2,2]" in text


def test_export_head_with_pallas_epilogue(tmp_path):
    """A real head (stage 1 of ResNet-Mini + fused quantize) must lower."""
    params = resnet.init(jax.random.PRNGKey(0), 10)

    def head(x, levels):
        feat = common.head_apply(resnet, params, x, 1)
        sym, scale, zero = quantize_with_params(feat, levels)
        return sym.reshape(-1), scale, zero

    x = jax.ShapeDtypeStruct((1, 32, 32, 3), jnp.float32)
    lv = jax.ShapeDtypeStruct((), jnp.float32)
    path = str(tmp_path / "head.hlo.txt")
    text = export_fn(head, (x, lv), path)
    assert "HloModule" in text
    assert "s32[" in text  # integer symbol output present


def test_export_tail_with_dequant_prologue(tmp_path):
    params = resnet.init(jax.random.PRNGKey(0), 10)
    feat_shape = (1, 32, 32, 16)
    t = int(np.prod(feat_shape))

    def tail(sym, scale, zero):
        feat = aiq_dequantize(sym, scale, zero).reshape(feat_shape)
        return (common.tail_apply(resnet, params, feat, 1),)

    sym = jax.ShapeDtypeStruct((t,), jnp.int32)
    sc = jax.ShapeDtypeStruct((), jnp.float32)
    path = str(tmp_path / "tail.hlo.txt")
    text = export_fn(tail, (sym, sc, sc), path)
    assert "HloModule" in text


def test_quantize_dequantize_through_hlo_semantics():
    """Head-epilogue then tail-prologue (as jitted graphs) reconstructs
    within one quantization step — the same invariant the Rust runtime
    relies on across the two artifacts."""
    x = jax.random.normal(jax.random.PRNGKey(1), (512,)) * jnp.float32(2.0)
    levels = jnp.float32(15.0)
    sym, scale, zero = jax.jit(quantize_with_params)(x, levels)
    back = jax.jit(aiq_dequantize)(sym, scale, zero)
    assert np.abs(np.asarray(back) - np.asarray(x)).max() <= float(scale) + 1e-5


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_manifest_references_existing_files():
    base = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    for m in manifest["vision"]:
        assert os.path.exists(os.path.join(base, m["test_data"]))
        for s in m["splits"]:
            for p in s["artifacts"].values():
                assert os.path.exists(os.path.join(base, p)), p
    for m in manifest["lm"]:
        for p in m["artifacts"].values():
            assert os.path.exists(os.path.join(base, p)), p
        for t in m["tasks"]:
            assert os.path.exists(os.path.join(base, t["path"]))
