"""AOT artifact builder: train → lower → export.

Usage (from ``python/``)::

    python -m compile.aot --out ../artifacts [--fast]

Produces under the output directory:

* ``models/*.hlo.txt``  — head/tail HLO pairs per (model, dataset, split,
  batch), in both quantized (Pallas epilogue/prologue) and raw variants.
* ``data/*.bin``        — vision test sets and LM multiple-choice tasks.
* ``cache/*.npz``       — trained parameters (reused on rebuild).
* ``manifest.json``     — the index the Rust runtime loads.

The quantized head ends with the Layer-1 fused quantize kernel
(min/max → scale/zero → int symbols) and the quantized tail begins with
the Layer-1 dequantize kernel, so the entire request-path compute is
inside the two HLO artifacts; Rust only moves integers through the
CSR+rANS pipeline between them.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import train as T
from .hlo import export_fn
from .kernels.dequantize import aiq_dequantize
from .kernels.quantize import quantize_with_params
from .models import VISION_MODELS, common, llama_mini


SEED = 42

# (model, dataset, splits, batches) export plan. ResNet doubles as the
# Table-2/4 subject on both datasets; the rest cover Table 5 at SL2.
# batches beyond 1 are exported only at SL2 (the serving-bench route) to
# bound artifact-build time.
VISION_PLAN = [
    ("resnet_mini", "synth_a", [1, 2, 3, 4], [1, 8]),  # b8 only at SL2
    ("resnet_mini", "synth_b", [1, 2, 3, 4], [1]),
    ("vgg_mini", "synth_b", [2], [1]),
    ("mobilenet_mini", "synth_b", [2], [1]),
    ("densenet_mini", "synth_b", [2], [1]),
    ("efficientnet_mini", "synth_b", [2], [1]),
    ("swin_mini", "synth_b", [2], [1]),
]

LM_SIZES = ["s", "m"]
LM_TASK_ITEMS = 32


def _vision_head_fn(model, params, sl):
    def fn(x, levels):
        feat = common.head_apply(model, params, x, sl)
        sym, scale, zero = quantize_with_params(feat, levels)
        return sym.reshape(-1), scale, zero

    return fn


def _vision_head_raw_fn(model, params, sl):
    def fn(x):
        return (common.head_apply(model, params, x, sl).reshape(-1),)

    return fn


def _vision_tail_fn(model, params, sl, feat_shape):
    def fn(sym_flat, scale, zero):
        feat = aiq_dequantize(sym_flat, scale, zero).reshape(feat_shape)
        return (common.tail_apply(model, params, feat, sl),)

    return fn


def _vision_tail_raw_fn(model, params, sl, feat_shape):
    def fn(feat_flat):
        return (common.tail_apply(model, params, feat_flat.reshape(feat_shape), sl),)

    return fn


def _lm_head_fn(params, size, sl):
    def fn(tokens, levels):
        hidden = llama_mini.head_apply(params, tokens, size, sl)
        sym, scale, zero = quantize_with_params(hidden, levels)
        return sym.reshape(-1), scale, zero

    return fn


def _lm_head_raw_fn(params, size, sl):
    def fn(tokens):
        return (llama_mini.head_apply(params, tokens, size, sl).reshape(-1),)

    return fn


def _lm_tail_fn(params, size, sl, hidden_shape):
    def fn(sym_flat, scale, zero):
        hidden = aiq_dequantize(sym_flat, scale, zero).reshape(hidden_shape)
        return (llama_mini.tail_apply(params, hidden, size, sl),)

    return fn


def _lm_tail_raw_fn(params, size, sl, hidden_shape):
    def fn(hidden_flat):
        return (llama_mini.tail_apply(params, hidden_flat.reshape(hidden_shape), size, sl),)

    return fn


def build_vision(out_dir: str, fast: bool, log=print):
    """Train the vision zoo and export all planned artifacts."""
    steps = 30 if fast else 80
    n_train = 384 if fast else 1024
    n_test = 96 if fast else 256
    entries = []
    trained = {}
    datasets = {}

    for spec_name in sorted({d for _, d, _, _ in VISION_PLAN}):
        spec = D.VISION_SPECS[spec_name]
        log(f"  dataset {spec_name}: {spec.num_classes} classes")
        datasets[spec_name] = D.make_vision_dataset(spec, n_train, n_test)
        x_te, y_te = datasets[spec_name][2], datasets[spec_name][3]
        os.makedirs(os.path.join(out_dir, "data"), exist_ok=True)
        D.write_vision_bin(
            os.path.join(out_dir, "data", f"{spec_name}_test.bin"),
            x_te,
            y_te,
            spec.num_classes,
        )

    for model_name, ds_name, splits, batches in VISION_PLAN:
        model = VISION_MODELS[model_name]
        spec = D.VISION_SPECS[ds_name]
        x_tr, y_tr, x_te, y_te = datasets[ds_name]
        key = (model_name, ds_name)
        if key not in trained:
            mode = "fast" if fast else "full"
            cpath = T.cache_path(
                os.path.join(out_dir, "cache"), f"{model_name}_{ds_name}_{mode}"
            )
            like = model.init(jax.random.PRNGKey(SEED), spec.num_classes)
            params = T.load_params(cpath, like)
            if params is None:
                t0 = time.time()
                params = T.train_vision(
                    model, spec.num_classes, x_tr, y_tr, steps=steps, batch=64,
                    lr=1e-3, seed=SEED, log=log,
                )
                log(f"  trained {model_name}/{ds_name} in {time.time() - t0:.1f}s")
                os.makedirs(os.path.dirname(cpath), exist_ok=True)
                T.save_params(cpath, params)
            trained[key] = params
        params = trained[key]
        acc = T.eval_vision(model, params, x_te, y_te)
        log(f"  {model_name}/{ds_name} baseline accuracy {acc:.4f}")

        split_entries = []
        for sl in splits:
            for b in batches:
                if b != 1 and sl != 2:
                    continue  # large batches only at the serving split
                x_spec = jax.ShapeDtypeStruct((b, D.IMG_H, D.IMG_W, D.IMG_C), jnp.float32)
                feat = jax.eval_shape(
                    functools.partial(common.head_apply, model, params, sl=sl), x_spec
                )
                feat_shape = tuple(feat.shape)
                t = int(np.prod(feat_shape))
                base = f"{model_name}_{ds_name}_sl{sl}_b{b}"
                lv = jax.ShapeDtypeStruct((), jnp.float32)
                sym_spec = jax.ShapeDtypeStruct((t,), jnp.int32)
                feat_flat = jax.ShapeDtypeStruct((t,), jnp.float32)
                scalar = jax.ShapeDtypeStruct((), jnp.float32)
                paths = {
                    "head": f"models/{base}_head.hlo.txt",
                    "tail": f"models/{base}_tail.hlo.txt",
                    "head_raw": f"models/{base}_head_raw.hlo.txt",
                    "tail_raw": f"models/{base}_tail_raw.hlo.txt",
                }
                export_fn(
                    _vision_head_fn(model, params, sl), (x_spec, lv),
                    os.path.join(out_dir, paths["head"]),
                )
                export_fn(
                    _vision_tail_fn(model, params, sl, feat_shape),
                    (sym_spec, scalar, scalar),
                    os.path.join(out_dir, paths["tail"]),
                )
                export_fn(
                    _vision_head_raw_fn(model, params, sl), (x_spec,),
                    os.path.join(out_dir, paths["head_raw"]),
                )
                export_fn(
                    _vision_tail_raw_fn(model, params, sl, feat_shape), (feat_flat,),
                    os.path.join(out_dir, paths["tail_raw"]),
                )
                split_entries.append(
                    {
                        "sl": sl,
                        "batch": b,
                        "feature_shape": list(feat_shape),
                        "feature_len": t,
                        "artifacts": paths,
                    }
                )
                log(f"    exported {base} (feature {feat_shape})")
        entries.append(
            {
                "name": f"{model_name}_{ds_name}",
                "model": model_name,
                "dataset": ds_name,
                "num_classes": spec.num_classes,
                "input_shape": [1, D.IMG_H, D.IMG_W, D.IMG_C],
                "baseline_accuracy": acc,
                "test_data": f"data/{ds_name}_test.bin",
                "splits": split_entries,
            }
        )
    return entries


def build_lm(out_dir: str, fast: bool, log=print):
    """Train both Llama-Mini sizes, export artifacts and task binaries."""
    steps = 40 if fast else 100
    items = 12 if fast else LM_TASK_ITEMS
    entries = []

    os.makedirs(os.path.join(out_dir, "data"), exist_ok=True)
    task_files = []
    for ti, task in enumerate(D.LM_TASKS):
        path = f"data/lm_{task}.bin"
        D.write_mc_task_bin(os.path.join(out_dir, path), task, items, seed=900 + ti)
        task_files.append({"name": task, "path": path, "n_items": items})

    for size in LM_SIZES:
        cfg = llama_mini.SIZES[size]
        sl = llama_mini.default_split(size)
        mode = "fast" if fast else "full"
        cpath = T.cache_path(os.path.join(out_dir, "cache"), f"llama_mini_{size}_{mode}")
        like = llama_mini.init(jax.random.PRNGKey(SEED + 13), size)
        params = T.load_params(cpath, like)
        if params is None:
            t0 = time.time()
            params = T.train_lm(size, steps=steps, batch=32, lr=1e-3, seed=SEED,
                                corpus_size=256 if fast else 384, log=log)
            log(f"  trained llama_mini_{size} in {time.time() - t0:.1f}s")
            os.makedirs(os.path.dirname(cpath), exist_ok=True)
            T.save_params(cpath, params)

        baselines = {}
        for tf in task_files:
            baselines[tf["name"]] = T.eval_lm_mc(
                params, size, tf["name"], n_items=6 if fast else 8, seed=1234
            )
        log(f"  llama_mini_{size} baseline MC accuracy: "
            + ", ".join(f"{k}={v:.2f}" for k, v in baselines.items()))

        b = D.N_CHOICES  # score all choices of one item as a batch
        tok_spec = jax.ShapeDtypeStruct((b, D.SEQ_LEN), jnp.int32)
        hidden_shape = (b, D.SEQ_LEN, cfg["dim"])
        t = int(np.prod(hidden_shape))
        lv = jax.ShapeDtypeStruct((), jnp.float32)
        sym_spec = jax.ShapeDtypeStruct((t,), jnp.int32)
        hidden_flat = jax.ShapeDtypeStruct((t,), jnp.float32)
        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        base = f"llama_mini_{size}_sl{sl}_b{b}"
        paths = {
            "head": f"models/{base}_head.hlo.txt",
            "tail": f"models/{base}_tail.hlo.txt",
            "head_raw": f"models/{base}_head_raw.hlo.txt",
            "tail_raw": f"models/{base}_tail_raw.hlo.txt",
        }
        export_fn(_lm_head_fn(params, size, sl), (tok_spec, lv),
                  os.path.join(out_dir, paths["head"]))
        export_fn(_lm_tail_fn(params, size, sl, hidden_shape),
                  (sym_spec, scalar, scalar), os.path.join(out_dir, paths["tail"]))
        export_fn(_lm_head_raw_fn(params, size, sl), (tok_spec,),
                  os.path.join(out_dir, paths["head_raw"]))
        export_fn(_lm_tail_raw_fn(params, size, sl, hidden_shape), (hidden_flat,),
                  os.path.join(out_dir, paths["tail_raw"]))
        log(f"    exported {base} (hidden {hidden_shape})")

        entries.append(
            {
                "name": f"llama_mini_{size}",
                "size": size,
                "vocab": D.VOCAB,
                "seq_len": D.SEQ_LEN,
                "dim": cfg["dim"],
                "layers": cfg["layers"],
                "split": sl,
                "batch": b,
                "hidden_len": t,
                "baseline_accuracy": baselines,
                "artifacts": paths,
                "tasks": task_files,
            }
        )
    return entries


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="tiny datasets / few steps (CI smoke builds)")
    ap.add_argument("--skip-lm", action="store_true")
    ap.add_argument("--skip-vision", action="store_true")
    args = ap.parse_args()
    fast = args.fast or os.environ.get("RANS_SC_FAST") == "1"

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    manifest = {"version": 1, "seed": SEED, "fast": fast, "vision": [], "lm": []}

    def flush():
        # Write incrementally so consumers can start as soon as the
        # vision artifacts land (the LM build takes several more minutes).
        with open(os.path.join(out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)

    if not args.skip_vision:
        print("[aot] building vision artifacts")
        manifest["vision"] = build_vision(out_dir, fast)
        flush()
    if not args.skip_lm:
        print("[aot] building lm artifacts")
        manifest["lm"] = build_lm(out_dir, fast)
    flush()
    print(f"[aot] wrote manifest.json ({time.time() - t0:.1f}s total)")


if __name__ == "__main__":
    main()
