"""Build-time training loops (Layer 2).

Minimal Adam + cross-entropy, jitted. Vision models train on the
synthetic grating datasets; Llama-Mini trains next-token on the mixed
task corpus. Runs once under ``make artifacts``; trained params are
cached as .npz under ``artifacts/cache`` keyed by a config hash.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from .models import common, llama_mini


# ----------------------------------------------------------------- adam

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def softmax_xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))


# --------------------------------------------------------------- vision

def train_vision(model, num_classes, x_tr, y_tr, steps, batch, lr, seed=0, log=print):
    """Train a split-protocol vision model; returns trained params."""
    key = jax.random.PRNGKey(seed)
    params = model.init(key, num_classes)

    def loss_fn(p, xb, yb):
        return softmax_xent(common.forward(model, p, xb), yb)

    @jax.jit
    def step(p, opt, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb)
        p, opt = adam_step(p, grads, opt, lr)
        return p, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(seed + 7)
    n = x_tr.shape[0]
    for i in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, opt, loss = step(params, opt, x_tr[idx], y_tr[idx])
        if log and (i % max(1, steps // 5) == 0 or i == steps - 1):
            log(f"    [{model.NAME}] step {i + 1}/{steps} loss {float(loss):.3f}")
    return params


def eval_vision(model, params, x_te, y_te, batch=64) -> float:
    """Top-1 accuracy of the full (uncompressed) model."""
    fwd = jax.jit(functools.partial(common.forward, model))
    correct = 0
    for i in range(0, x_te.shape[0], batch):
        logits = fwd(params, x_te[i : i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y_te[i : i + batch]))
    return correct / x_te.shape[0]


# -------------------------------------------------------------- language

def train_lm(size: str, steps, batch, lr, seed=0, corpus_size=4096, log=print):
    """Train Llama-Mini next-token on the synthetic task corpus."""
    key = jax.random.PRNGKey(seed + 13)
    params = llama_mini.init(key, size)
    corpus = D.gen_training_corpus(corpus_size, seed=seed + 31)

    def loss_fn(p, toks):
        logits = llama_mini.forward(p, toks[:, :-1], size)
        labels = toks[:, 1:]
        mask = (labels != D.PAD).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    @jax.jit
    def step(p, opt, toks):
        loss, grads = jax.value_and_grad(loss_fn)(p, toks)
        p, opt = adam_step(p, grads, opt, lr)
        return p, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(seed + 77)
    for i in range(steps):
        idx = rng.integers(0, corpus.shape[0], size=batch)
        params, opt, loss = step(params, opt, jnp.asarray(corpus[idx]))
        if log and (i % max(1, steps // 5) == 0 or i == steps - 1):
            log(f"    [llama_mini_{size}] step {i + 1}/{steps} loss {float(loss):.3f}")
    return params


def eval_lm_mc(params, size: str, task: str, n_items: int, seed: int) -> float:
    """Multiple-choice accuracy of the full model (logprob scoring)."""
    rng = np.random.default_rng(seed)
    fwd = jax.jit(functools.partial(llama_mini.forward, size=size))
    correct = 0
    for _ in range(n_items):
        choices, starts, lens, gold = D.gen_mc_item(task, rng)
        toks = jnp.asarray(choices)
        logits = fwd(params, toks)  # (C, T, V)
        logp = jax.nn.log_softmax(logits, axis=-1)
        scores = []
        for c in range(choices.shape[0]):
            s, ln = int(starts[c]), int(lens[c])
            # logits at t-1 predict token t.
            pos = np.arange(s, s + ln)
            lp = logp[c, pos - 1, choices[c, pos]]
            scores.append(float(jnp.sum(lp)))
        if int(np.argmax(scores)) == gold:
            correct += 1
    return correct / n_items


# ---------------------------------------------------------------- cache

def cache_path(cache_dir: str, name: str) -> str:
    return os.path.join(cache_dir, f"{name}.npz")


def save_params(path: str, params):
    flat, treedef = jax.tree_util.tree_flatten(params)
    np.savez_compressed(
        path, treedef=np.frombuffer(str(treedef).encode(), dtype=np.uint8),
        **{f"a{i}": np.asarray(x) for i, x in enumerate(flat)},
    )


def load_params(path: str, like):
    """Load params saved by :func:`save_params`, using ``like`` (a params
    pytree of the same structure) for the treedef."""
    if not os.path.exists(path):
        return None
    z = np.load(path)
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    flat = [jnp.asarray(z[f"a{i}"]) for i in range(len(flat_like))]
    if any(a.shape != b.shape for a, b in zip(flat, flat_like)):
        return None  # config changed; retrain
    return jax.tree_util.tree_unflatten(treedef, flat)
