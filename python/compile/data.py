"""Synthetic datasets + binary export.

The paper evaluates on CIFAR100/ImageNet and seven LLM benchmarks; none
of those assets exist in this environment, so we substitute procedurally
generated workloads with the properties the codec actually exercises
(documented in DESIGN.md §Substitutions):

* **Vision** — "grating + blob" class prototypes: each class is a fixed
  mixture of two oriented sinusoidal gratings and a Gaussian blob in a
  class-specific color; samples add jitter, shifts and noise. Small
  CNNs/transformers reach strong accuracy yet the task is not linearly
  separable, so quantization-induced accuracy deltas are measurable.
  ``synth_a`` (20 classes) stands in for CIFAR100, ``synth_b``
  (40 classes) for ImageNet.

* **Language** — seven multiple-choice suites over a 512-token vocab,
  each testing a different structural rule (retrieval, completion,
  arithmetic, majority, parity, first-token recall, indexed lookup) as
  analogues of MMLU/HellaSwag/ARC/PIQA/BoolQ/Winogrande/OpenBookQA.
  Items are (context, 4 choices, answer-span) tuples; the LM is trained
  on correct continuations drawn from the same distributions.

Binary formats are little-endian and documented field-by-field below;
``rust/src/data`` implements the mirror-image readers.
"""

from __future__ import annotations

import struct

import numpy as np

# ---------------------------------------------------------------- vision

IMG_H = IMG_W = 32
IMG_C = 3


class VisionSpec:
    """A synthetic vision dataset family."""

    def __init__(self, name: str, num_classes: int, seed: int):
        self.name = name
        self.num_classes = num_classes
        self.seed = seed


VISION_SPECS = {
    "synth_a": VisionSpec("synth_a", 20, 101),  # CIFAR100 analogue
    "synth_b": VisionSpec("synth_b", 40, 202),  # ImageNet analogue
}


def _class_prototype(rng: np.random.Generator):
    """Random grating+blob prototype parameters for one class."""
    return {
        "theta": rng.uniform(0, np.pi, size=2),
        "freq": rng.uniform(2.0, 8.0, size=2),
        "phase": rng.uniform(0, 2 * np.pi, size=2),
        "color": rng.uniform(-1.0, 1.0, size=(2, IMG_C)),
        "blob_xy": rng.uniform(8, 24, size=2),
        "blob_sigma": rng.uniform(3.0, 6.0),
        "blob_color": rng.uniform(-1.0, 1.0, size=IMG_C),
    }


def _render(proto, rng: np.random.Generator) -> np.ndarray:
    yy, xx = np.mgrid[0:IMG_H, 0:IMG_W].astype(np.float32)
    img = np.zeros((IMG_H, IMG_W, IMG_C), np.float32)
    for g in range(2):
        t = proto["theta"][g] + rng.normal(0, 0.05)
        f = proto["freq"][g] * (1.0 + rng.normal(0, 0.02))
        ph = proto["phase"][g] + rng.normal(0, 0.1)
        wave = np.sin(
            2 * np.pi * f * (xx * np.cos(t) + yy * np.sin(t)) / IMG_W + ph
        )
        img += wave[..., None] * proto["color"][g][None, None, :]
    bx, by = proto["blob_xy"] + rng.normal(0, 0.5, size=2)
    blob = np.exp(-(((xx - bx) ** 2 + (yy - by) ** 2) / (2 * proto["blob_sigma"] ** 2)))
    img += blob[..., None] * proto["blob_color"][None, None, :]
    # Random small shift + pixel noise.
    img = np.roll(img, rng.integers(-1, 2, size=2), axis=(0, 1))
    img += rng.normal(0, 0.20, img.shape).astype(np.float32)
    return img.astype(np.float32)


def make_vision_dataset(spec: VisionSpec, n_train: int, n_test: int):
    """Generate (x_train, y_train, x_test, y_test) for a spec."""
    rng = np.random.default_rng(spec.seed)
    protos = [_class_prototype(rng) for _ in range(spec.num_classes)]

    def batch(n, rng):
        ys = rng.integers(0, spec.num_classes, size=n)
        xs = np.stack([_render(protos[y], rng) for y in ys])
        return xs.astype(np.float32), ys.astype(np.int32)

    x_tr, y_tr = batch(n_train, np.random.default_rng(spec.seed + 1))
    x_te, y_te = batch(n_test, np.random.default_rng(spec.seed + 2))
    return x_tr, y_tr, x_te, y_te


VISION_MAGIC = b"RSCD"


def write_vision_bin(path: str, x: np.ndarray, y: np.ndarray, num_classes: int):
    """Vision test-set binary.

    Layout: magic "RSCD", u32 version=1, u32 count, u32 h, u32 w, u32 c,
    u32 num_classes, count×u32 labels, count·h·w·c f32 images (row-major
    NHWC).
    """
    n, h, w, c = x.shape
    with open(path, "wb") as f:
        f.write(VISION_MAGIC)
        f.write(struct.pack("<6I", 1, n, h, w, c, num_classes))
        f.write(y.astype("<u4").tobytes())
        f.write(x.astype("<f4").tobytes())


# -------------------------------------------------------------- language

VOCAB = 512
SEQ_LEN = 64
N_CHOICES = 4
ANS_LEN = 4
PAD, SEP = 0, 1
# Content tokens live in [8, VOCAB).
TOK_LO = 8

LM_TASKS = [
    "retrieval",   # MMLU analogue: key→value lookup from context
    "completion",  # HellaSwag: continue a repeating motif
    "arithmetic",  # ARC: next element of an arithmetic progression
    "majority",    # PIQA: most frequent context token
    "parity",      # BoolQ: even/odd count of a marker token
    "recall",      # Winogrande: first-token recall
    "indexed",     # OpenBookQA: token at indexed position
]


def _rand_tok(rng, n=1):
    return rng.integers(TOK_LO, VOCAB, size=n)


def _gen_item(task: str, rng: np.random.Generator):
    """Returns (context_tokens, answer_tokens, distractor_fn)."""
    if task == "retrieval":
        keys = _rand_tok(rng, 6)
        vals = _rand_tok(rng, 6)
        ctx = np.empty(12, np.int64)
        ctx[0::2], ctx[1::2] = keys, vals
        qi = rng.integers(0, 6)
        ctx = np.concatenate([ctx, [keys[qi]]])
        ans = np.repeat(vals[qi], ANS_LEN)
    elif task == "completion":
        motif = _rand_tok(rng, rng.integers(2, 5))
        tiled = np.tile(motif, 16)  # long enough for context + answer
        ctx = tiled[:16]
        ans = tiled[16 : 16 + ANS_LEN]
    elif task == "arithmetic":
        a = int(rng.integers(TOK_LO, TOK_LO + 200))
        d = int(rng.integers(1, 9))
        seq = a + d * np.arange(8)
        ctx = (seq % (VOCAB - TOK_LO)) + TOK_LO
        nxt = a + d * (8 + np.arange(ANS_LEN))
        ans = (nxt % (VOCAB - TOK_LO)) + TOK_LO
    elif task == "majority":
        maj = int(_rand_tok(rng)[0])
        other = _rand_tok(rng, 8)
        ctx = np.concatenate([np.repeat(maj, 9), other])
        rng.shuffle(ctx)
        ans = np.repeat(maj, ANS_LEN)
    elif task == "parity":
        marker = TOK_LO + 1
        count = int(rng.integers(1, 9))
        filler = _rand_tok(rng, 14 - count)
        filler = filler[filler != marker]
        ctx = np.concatenate([np.repeat(marker, count), filler])
        rng.shuffle(ctx)
        even_tok, odd_tok = TOK_LO + 2, TOK_LO + 3
        ans = np.repeat(even_tok if count % 2 == 0 else odd_tok, ANS_LEN)
    elif task == "recall":
        first = int(_rand_tok(rng)[0])
        rest = _rand_tok(rng, 12)
        ctx = np.concatenate([[first], rest])
        ans = np.repeat(first, ANS_LEN)
    elif task == "indexed":
        items = _rand_tok(rng, 8)
        idx = int(rng.integers(0, 8))
        idx_tok = TOK_LO + 4 + idx  # index encoded as a reserved token
        ctx = np.concatenate([items, [idx_tok]])
        ans = np.repeat(items[idx], ANS_LEN)
    else:
        raise ValueError(task)
    return ctx.astype(np.int64), ans.astype(np.int64)


def _assemble(ctx, ans):
    """context ⊕ SEP ⊕ answer, padded to SEQ_LEN; returns (tokens,
    score_start, score_len)."""
    toks = np.concatenate([ctx, [SEP], ans])
    start = len(ctx) + 1
    out = np.full(SEQ_LEN, PAD, np.int64)
    out[: len(toks)] = toks[:SEQ_LEN]
    return out, start, len(ans)


def gen_mc_item(task: str, rng: np.random.Generator):
    """One multiple-choice item: (choices[N_CHOICES][SEQ_LEN], starts,
    lens, correct_idx)."""
    ctx, ans = _gen_item(task, rng)
    choices, starts, lens = [], [], []
    correct = int(rng.integers(0, N_CHOICES))
    seen = {tuple(ans)}
    for i in range(N_CHOICES):
        if i == correct:
            a = ans
        else:
            # Distractor: same shape, different content. Some tasks have
            # tiny answer spaces (parity has two), so fall back to a
            # random in-vocab span after a bounded number of rule-based
            # attempts.
            a = None
            for _ in range(8):
                _, cand = _gen_item(task, rng)
                if tuple(cand) not in seen:
                    a = cand
                    break
            if a is None:
                while True:
                    cand = np.repeat(_rand_tok(rng)[0], ANS_LEN)
                    if tuple(cand) not in seen:
                        a = cand
                        break
            seen.add(tuple(a))
        toks, start, ln = _assemble(ctx, a)
        choices.append(toks)
        starts.append(start)
        lens.append(ln)
    return np.stack(choices), np.array(starts), np.array(lens), correct


def gen_training_corpus(n_seqs: int, seed: int) -> np.ndarray:
    """Next-token training sequences: correct continuations across all
    tasks (uniform mixture)."""
    rng = np.random.default_rng(seed)
    out = np.empty((n_seqs, SEQ_LEN), np.int64)
    for i in range(n_seqs):
        task = LM_TASKS[i % len(LM_TASKS)]
        ctx, ans = _gen_item(task, rng)
        toks, _, _ = _assemble(ctx, ans)
        out[i] = toks
    return out


LM_MAGIC = b"RSCT"


def write_mc_task_bin(path: str, task: str, n_items: int, seed: int):
    """Multiple-choice task binary.

    Layout: magic "RSCT", u32 version=1, u32 n_items, u32 n_choices,
    u32 seq_len, u32 vocab; then per item: u32 correct, then per choice:
    u32 score_start, u32 score_len, seq_len×u32 tokens.
    """
    rng = np.random.default_rng(seed)
    with open(path, "wb") as f:
        f.write(LM_MAGIC)
        f.write(struct.pack("<5I", 1, n_items, N_CHOICES, SEQ_LEN, VOCAB))
        for _ in range(n_items):
            choices, starts, lens, correct = gen_mc_item(task, rng)
            f.write(struct.pack("<I", correct))
            for c in range(N_CHOICES):
                f.write(struct.pack("<2I", int(starts[c]), int(lens[c])))
                f.write(choices[c].astype("<u4").tobytes())
