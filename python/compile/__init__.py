"""Build-time compile path: JAX models + Pallas kernels -> HLO artifacts.

Nothing in this package is imported at runtime; the Rust coordinator
consumes only the files written to ``artifacts/``.
"""
