"""Pallas per-row nonzero-count kernel (Layer 1).

Computes the modified-CSR `r` array (direct per-row counts, §3.1) for a
reshaped (N, K) symbol matrix: one grid step per row tile, a lane-wise
`!= background` mask reduced along K in VMEM. This is the CSR-prep the
paper runs on GPU; the Rust encoder consumes the counts to slice the
value/column streams without re-scanning the tensor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step.
ROW_BLOCK = 64


def _rowcount_kernel(sym_ref, bg_ref, o_ref):
    bg = bg_ref[0, 0]
    mask = (sym_ref[...] != bg).astype(jnp.int32)
    o_ref[...] = jnp.sum(mask, axis=1)


def row_nonzero_counts(sym2d, background):
    """Per-row count of entries != ``background`` for an (N, K) matrix."""
    n, k = sym2d.shape
    pad = (-n) % ROW_BLOCK
    if pad:
        # Padded rows are all-background → count 0; sliced off below.
        filler = jnp.broadcast_to(
            jnp.asarray(background, sym2d.dtype), (pad, k)
        )
        sym2d = jnp.concatenate([sym2d, filler], axis=0)
    nblocks = sym2d.shape[0] // ROW_BLOCK
    bg = jnp.asarray(background, jnp.int32).reshape(1, 1)
    out = pl.pallas_call(
        _rowcount_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, k), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((sym2d.shape[0],), jnp.int32),
        interpret=True,
    )(sym2d.astype(jnp.int32), bg)
    return out[:n]
