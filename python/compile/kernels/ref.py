"""Pure-jnp oracles for every Layer-1 kernel.

These are the correctness ground truth: pytest + hypothesis sweep the
Pallas kernels against these definitions, and the Rust `quant` module
mirrors the same semantics (ties-to-even rounding, edge saturation).
"""

from __future__ import annotations

import jax.numpy as jnp


def minmax_ref(x):
    """Global (min, max) of a tensor, as f32 scalars."""
    flat = x.reshape(-1).astype(jnp.float32)
    return jnp.min(flat), jnp.max(flat)


def aiq_params_ref(x_min, x_max, levels):
    """AIQ scale and zero point (Eq. 6).

    ``levels = 2^Q - 1`` is passed as data so one lowered graph serves
    every bit-width. Degenerate ranges fall back to scale = 1.
    """
    raw = (x_max - x_min) / levels
    # Subnormal ranges (1/raw overflows f32) are degenerate too, so the
    # quantize reciprocal stays finite; matches the Rust fit path.
    scale = jnp.where((raw > 0) & jnp.isfinite(1.0 / raw), raw, 1.0)
    zero = jnp.clip(jnp.round(-x_min / scale), 0, levels)
    return scale, zero


def aiq_quantize_ref(x, scale, zero, levels):
    """Quantize to integer symbols in {0..levels} (Eq. 6).

    Multiplies by the exact reciprocal of ``scale`` rather than dividing
    per element — the same arithmetic as the Pallas kernel and the Rust
    ``quant::quantize`` hot loop. The kernel and this oracle lower
    identically (exact agreement); vs. Rust, XLA's FMA contraction of
    the multiply-add can shift values at exact rounding boundaries by
    one symbol, so cross-language checks should compare within one
    quantization step rather than bit-for-bit.
    """
    inv = jnp.float32(1.0) / scale
    v = jnp.round(x.astype(jnp.float32) * inv + zero)
    return jnp.clip(v, 0, levels).astype(jnp.int32)


def aiq_dequantize_ref(sym, scale, zero):
    """Inverse of :func:`aiq_quantize_ref` up to quantization error."""
    return (sym.astype(jnp.float32) - zero) * scale


def row_nonzero_counts_ref(sym2d, background):
    """Per-row count of entries != background (modified-CSR `r` array)."""
    return jnp.sum((sym2d != background).astype(jnp.int32), axis=1)


def symbol_histogram_ref(sym, alphabet: int):
    """Frequency histogram over a static alphabet size."""
    flat = sym.reshape(-1)
    return jnp.sum(
        (flat[:, None] == jnp.arange(alphabet)[None, :]).astype(jnp.int32), axis=0
    )
