"""Layer-1 Pallas kernels for the compression pipeline hot spots.

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret-mode lowering (plain HLO ops)
is the correctness-carrying path; real-TPU performance is estimated from
BlockSpec tiling in DESIGN.md §Hardware-Adaptation.
"""

from .quantize import aiq_quantize, minmax
from .dequantize import aiq_dequantize
from .rowcount import row_nonzero_counts
from .histogram import symbol_histogram

__all__ = [
    "aiq_quantize",
    "aiq_dequantize",
    "minmax",
    "row_nonzero_counts",
    "symbol_histogram",
]
