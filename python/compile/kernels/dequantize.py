"""Pallas AIQ dequantization kernel (Layer 1).

The tail-artifact prologue: `(sym − z) · s` over VMEM tiles, restoring
the float feature the cloud-side model consumes. Elementwise, so the
BlockSpec schedule is the same flat tiling as the quantizer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quantize import BLOCK


def _dequantize_kernel(sym_ref, scale_ref, zero_ref, o_ref):
    s = scale_ref[0, 0]
    z = zero_ref[0, 0]
    o_ref[...] = (sym_ref[...].astype(jnp.float32) - z) * s


def aiq_dequantize(sym, scale, zero):
    """Dequantize int32 symbols back to f32."""
    orig_shape = sym.shape
    if sym.size == 0:
        return jnp.zeros(orig_shape, jnp.float32)
    flat = sym.reshape(-1)
    t = flat.shape[0]
    pad = (-t) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    nblocks = flat.shape[0] // BLOCK
    as11 = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((flat.shape[0],), jnp.float32),
        interpret=True,
    )(flat, as11(scale), as11(zero))
    return out[:t].reshape(orig_shape)
