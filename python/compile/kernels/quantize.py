"""Pallas AIQ quantization kernels (Layer 1).

GPU→TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
kernel assigns a threadblock per tensor slab and reduces min/max through
shared memory. Here the HBM→VMEM schedule is expressed with BlockSpec
tiles over a flattened (BLOCK,) grid:

* :func:`minmax` — two-pass grid reduction: each grid step writes a
  per-block partial (min, max) pair; the scalar combine happens in the
  surrounding jax graph (Layer 2) where XLA fuses it.
* :func:`aiq_quantize` — elementwise `clip(round(x·(1/s) + z), 0, levels)`
  over VMEM tiles; `scale`/`zero`/`levels` ride along as (1,1) scalars so
  one lowered graph serves every bit-width Q. The scale reciprocal is
  taken once per tile so the element loop is divide-free, matching the
  Rust `quant::quantize` hot loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flat tile size: 8 KiB of f32 per block — comfortably VMEM-resident
# alongside the output tile on real hardware.
BLOCK = 2048


def _pad_flat(x, fill):
    """Flatten and right-pad to a BLOCK multiple with ``fill``."""
    flat = x.reshape(-1)
    t = flat.shape[0]
    pad = (-t) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.full((pad,), fill, flat.dtype)])
    return flat, t


def _minmax_kernel(x_ref, mn_ref, mx_ref):
    blk = x_ref[...]
    mn_ref[0] = jnp.min(blk)
    mx_ref[0] = jnp.max(blk)


def minmax(x):
    """Global (min, max) of ``x`` via a block-parallel partial reduction."""
    x = x.astype(jnp.float32)
    # Pad with the first element so padding never wins the reduction.
    first = x.reshape(-1)[0]
    flat, _ = _pad_flat(x, first)
    nblocks = flat.shape[0] // BLOCK
    mn, mx = pl.pallas_call(
        _minmax_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        ],
        interpret=True,
    )(flat)
    # Layer-2 combine of the per-block partials.
    return jnp.min(mn), jnp.max(mx)


def _quantize_kernel(x_ref, scale_ref, zero_ref, levels_ref, o_ref):
    # One exact IEEE divide per tile; the per-element loop is a multiply.
    # Same arithmetic as QuantParams::inv_scale() on the Rust side
    # (exactly equal except where XLA contracts the multiply-add into an
    # FMA, which can differ from Rust's two-rounding form by 1 ulp
    # before rounding — symbols may differ only at exact .5 boundaries).
    inv = 1.0 / scale_ref[0, 0]
    z = zero_ref[0, 0]
    lv = levels_ref[0, 0]
    v = jnp.round(x_ref[...] * inv + z)
    o_ref[...] = jnp.clip(v, 0.0, lv).astype(jnp.int32)


def aiq_quantize(x, scale, zero, levels):
    """Quantize ``x`` to int32 symbols in {0..levels} (Eq. 6).

    ``scale``, ``zero``, ``levels`` are scalar arrays (traced data, not
    Python constants).
    """
    x = x.astype(jnp.float32)
    orig_shape = x.shape
    if x.size == 0:
        return jnp.zeros(orig_shape, jnp.int32)
    flat, t = _pad_flat(x, jnp.float32(0))
    nblocks = flat.shape[0] // BLOCK
    as11 = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _quantize_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((flat.shape[0],), jnp.int32),
        interpret=True,
    )(flat, as11(scale), as11(zero), as11(levels))
    return out[:t].reshape(orig_shape)


def quantize_with_params(x, levels):
    """Fused head epilogue: min/max → params → symbols.

    Returns ``(symbols int32, scale f32, zero f32)``; this is the graph
    appended to every exported head artifact.
    """
    x_min, x_max = minmax(x)
    raw = (x_max - x_min) / levels
    # Degenerate OR subnormal range (1/raw would overflow f32) falls
    # back to scale = 1, mirroring QuantParams::from_min_max so the
    # reciprocal in the quantize kernel is always finite.
    scale = jnp.where((raw > 0) & jnp.isfinite(1.0 / raw), raw, 1.0)
    zero = jnp.clip(jnp.round(-x_min / scale), 0.0, levels)
    sym = aiq_quantize(x, scale, zero, levels)
    return sym, scale, zero
