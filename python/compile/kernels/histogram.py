"""Pallas symbol-histogram kernel (Layer 1).

Frequency-table prep for rANS. CUDA implementations scatter with atomics;
the TPU idiom is scatter-free: each grid step builds a one-hot matrix of
its symbol tile and reduces it — expressible as `ones(1,B) @ one_hot`
on the MXU. Partials accumulate into a single output block across grid
steps (`o += partial`, initialized at step 0), the standard Pallas
grid-accumulation pattern.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Symbols per grid step.
BLOCK = 1024


def _hist_kernel(sym_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    alphabet = o_ref.shape[0]
    onehot = (sym_ref[...][:, None] == jnp.arange(alphabet)[None, :]).astype(jnp.int32)
    o_ref[...] += jnp.sum(onehot, axis=0)


def symbol_histogram(sym, alphabet: int):
    """Histogram of int symbols over a static ``alphabet`` size.

    Out-of-range padding uses symbol value ``alphabet`` (one past the
    end), which the one-hot match drops, so padded tails do not bias the
    counts.
    """
    flat = sym.reshape(-1).astype(jnp.int32)
    t = flat.shape[0]
    if t == 0:
        # Empty input: zero counts (a zero-step grid is not lowerable).
        return jnp.zeros((alphabet,), jnp.int32)
    pad = (-t) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.full((pad,), alphabet, jnp.int32)])
    nblocks = flat.shape[0] // BLOCK
    return pl.pallas_call(
        _hist_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((alphabet,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((alphabet,), jnp.int32),
        interpret=True,
    )(flat)
