"""ResNet-Mini: BasicBlock residual stack (ResNet-34/50 analogue).

Four stages of two pre-norm basic blocks each, widths 16/32/64/128,
stride-2 downsampling between stages. SL1–SL4 cut after each stage —
the same split-point family Table 4 sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L

NAME = "resnet_mini"
SPLITS = [1, 2, 3, 4]
WIDTHS = [16, 32, 64, 128]
BLOCKS_PER_STAGE = 2


def _init_block(key, cin, cout, stride):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "n1": L.init_norm(cin),
        "c1": L.init_conv(k1, 3, 3, cin, cout),
        "n2": L.init_norm(cout),
        "c2": L.init_conv(k2, 3, 3, cout, cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = L.init_conv(k3, 1, 1, cin, cout)
    return p


def _stride_of(s: int, b: int) -> int:
    """Stride is structural (stage/block position), kept out of params so
    jit sees it as static."""
    return 2 if (b == 0 and s > 0) else 1


def _block(p, x, stride):
    h = L.channel_norm(p["n1"], x)
    h = L.relu(h)
    shortcut = L.conv2d(p["proj"], h, stride=stride) if "proj" in p else x
    h = L.conv2d(p["c1"], h, stride=stride)
    h = L.relu(L.channel_norm(p["n2"], h))
    h = L.conv2d(p["c2"], h)
    return L.relu(shortcut + h)


def init(key, num_classes):
    keys = jax.random.split(key, 32)
    ki = iter(keys)
    params = {"stem": L.init_conv(next(ki), 3, 3, 3, WIDTHS[0])}
    cin = WIDTHS[0]
    for s, cout in enumerate(WIDTHS):
        blocks = []
        for b in range(BLOCKS_PER_STAGE):
            blocks.append(_init_block(next(ki), cin, cout, _stride_of(s, b)))
            cin = cout
        params[f"stage{s + 1}"] = blocks
    params["head_norm"] = L.init_norm(WIDTHS[-1])
    params["fc"] = L.init_dense(next(ki), WIDTHS[-1], num_classes)
    return params


def stages(params):
    def make(s):
        def run(x):
            if s == 0:
                x = L.relu(L.conv2d(params["stem"], x))
            for b, bp in enumerate(params[f"stage{s + 1}"]):
                x = _block(bp, x, _stride_of(s, b))
            return x

        return run

    return [make(s) for s in range(4)]


def classifier(params, feat):
    x = L.channel_norm(params["head_norm"], feat)
    x = L.global_avg_pool(x)
    return L.dense(params["fc"], x)
