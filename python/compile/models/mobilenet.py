"""MobileNet-Mini: inverted residuals + depthwise separable convs
(MobileNetV2 analogue).

Four stages of two inverted-residual blocks, expansion 4.
"""

from __future__ import annotations

import jax

from .. import layers as L

NAME = "mobilenet_mini"
SPLITS = [1, 2, 3, 4]
WIDTHS = [16, 24, 48, 96]
EXPANSION = 4


def _init_ir(key, cin, cout):
    k1, k2, k3 = jax.random.split(key, 3)
    hidden = cin * EXPANSION
    return {
        "expand": L.init_conv(k1, 1, 1, cin, hidden),
        "n1": L.init_norm(hidden),
        "dw": L.init_conv(k2, 3, 3, 1, hidden),  # depthwise: in=1, groups=C
        "n2": L.init_norm(hidden),
        "project": L.init_conv(k3, 1, 1, hidden, cout),
        "n3": L.init_norm(cout),
    }


def _ir_block(p, x, stride):
    cin = x.shape[-1]
    h = L.relu(L.channel_norm(p["n1"], L.conv2d(p["expand"], x)))
    h = L.relu(L.channel_norm(p["n2"], L.depthwise_conv2d(p["dw"], h, stride=stride)))
    h = L.channel_norm(p["n3"], L.conv2d(p["project"], h))
    if stride == 1 and cin == h.shape[-1]:
        h = h + x  # linear bottleneck residual
    return h


def _stride_of(s: int, b: int) -> int:
    return 2 if (b == 0 and s > 0) else 1


def init(key, num_classes):
    keys = jax.random.split(key, 24)
    ki = iter(keys)
    params = {"stem": L.init_conv(next(ki), 3, 3, 3, WIDTHS[0])}
    cin = WIDTHS[0]
    for s, cout in enumerate(WIDTHS):
        blocks = []
        for _b in range(2):
            blocks.append(_init_ir(next(ki), cin, cout))
            cin = cout
        params[f"stage{s + 1}"] = blocks
    params["head_norm"] = L.init_norm(WIDTHS[-1])
    params["fc"] = L.init_dense(next(ki), WIDTHS[-1], num_classes)
    return params


def stages(params):
    def make(s):
        def run(x):
            if s == 0:
                x = L.relu(L.conv2d(params["stem"], x))
            for b, bp in enumerate(params[f"stage{s + 1}"]):
                x = _ir_block(bp, x, _stride_of(s, b))
            return x

        return run

    return [make(s) for s in range(4)]


def classifier(params, feat):
    x = L.channel_norm(params["head_norm"], feat)
    x = L.global_avg_pool(x)
    return L.dense(params["fc"], x)
