"""Layer-2 model zoo: mini analogues of the paper's architectures.

Every vision model follows the split protocol in :mod:`common`: an
ordered list of stages; split layer SLk cuts after stage k, the head
runs on the edge, the tail on the cloud.
"""

from . import common, densenet, efficientnet, llama_mini, mobilenet, resnet, swin, vgg

VISION_MODELS = {
    "resnet_mini": resnet,
    "vgg_mini": vgg,
    "mobilenet_mini": mobilenet,
    "densenet_mini": densenet,
    "efficientnet_mini": efficientnet,
    "swin_mini": swin,
}

__all__ = ["common", "VISION_MODELS", "llama_mini"]
