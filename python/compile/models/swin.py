"""Swin-Mini: windowed self-attention transformer with patch merging
(Swin-T analogue).

Patch-embed 4×4 → three stages of window-attention blocks (window 4,
alternating shifted windows) with patch merging between stages, plus a
final attention stage at the coarsest resolution. Features stay NHWC at
the stage boundaries so the split/compress path is identical to the CNNs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L

NAME = "swin_mini"
SPLITS = [1, 2, 3, 4]
EMBED = 48
WINDOW = 4
HEADS = 4


def _window_partition(x, w):
    b, h, wd, c = x.shape
    x = x.reshape(b, h // w, w, wd // w, w, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)  # b, nh, nw, w, w, c
    return x.reshape(-1, w * w, c)


def _window_merge(wins, w, b, h, wd, c):
    x = wins.reshape(b, h // w, wd // w, w, w, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, h, wd, c)


def _init_block(key, dim):
    k1, k2 = jax.random.split(key)
    return {
        "n1": L.init_norm(dim),
        "attn": L.init_attention(k1, dim),
        "n2": L.init_norm(dim),
        "mlp": L.init_mlp(k2, dim, dim * 2),
    }


def _block(p, x, shift):
    b, h, w, c = x.shape
    # Effective window shrinks at coarse resolutions; shifting is a no-op
    # once the window covers the whole feature map.
    we = min(WINDOW, h, w)
    do_shift = shift and we < h
    res = x
    y = L.channel_norm(p["n1"], x)
    if do_shift:
        y = jnp.roll(y, shift=(-we // 2, -we // 2), axis=(1, 2))
    wins = _window_partition(y, we)
    wins = L.attention(p["attn"], wins, heads=HEADS)
    y = _window_merge(wins, we, b, h, w, c)
    if do_shift:
        y = jnp.roll(y, shift=(we // 2, we // 2), axis=(1, 2))
    x = res + y
    return x + L.mlp(p["mlp"], L.channel_norm(p["n2"], x))


def _init_merge(key, dim):
    return {"n": L.init_norm(dim * 4), "proj": L.init_dense(key, dim * 4, dim * 2)}


def _merge(p, x):
    b, h, w, c = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, h // 2, w // 2, 4 * c)
    return L.dense(p["proj"], L.channel_norm(p["n"], x))


def init(key, num_classes):
    keys = jax.random.split(key, 24)
    ki = iter(keys)
    params = {"embed": L.init_conv(next(ki), 4, 4, 3, EMBED)}
    dim = EMBED
    for s in range(4):
        params[f"stage{s + 1}"] = [
            _init_block(next(ki), dim),
            _init_block(next(ki), dim),
        ]
        if s < 2:
            params[f"merge{s + 1}"] = _init_merge(next(ki), dim)
            dim *= 2
    params["head_norm"] = L.init_norm(dim)
    params["fc"] = L.init_dense(next(ki), dim, num_classes)
    return params


def stages(params):
    def make(s):
        def run(x):
            if s == 0:
                # 32×32×3 → 8×8×EMBED patches.
                x = L.conv2d(params["embed"], x, stride=4, padding="VALID")
            for i, bp in enumerate(params[f"stage{s + 1}"]):
                x = _block(bp, x, shift=(i % 2 == 1))
            if s < 2:
                x = _merge(params[f"merge{s + 1}"], x)
            return x

        return run

    return [make(s) for s in range(4)]


def classifier(params, feat):
    x = L.channel_norm(params["head_norm"], feat)
    x = jnp.mean(x, axis=(1, 2))
    return L.dense(params["fc"], x)
