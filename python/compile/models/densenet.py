"""DenseNet-Mini: dense blocks with channel concatenation + transitions
(DenseNet121 analogue).

Four dense blocks (3 layers, growth 12) separated by 1×1 transition
convs with average-pool downsampling.
"""

from __future__ import annotations

import jax

from .. import layers as L

NAME = "densenet_mini"
SPLITS = [1, 2, 3, 4]
GROWTH = 12
LAYERS_PER_BLOCK = 3
STEM = 24


def _init_dense_layer(key, cin):
    return {"n": L.init_norm(cin), "c": L.init_conv(key, 3, 3, cin, GROWTH)}


def _dense_layer(p, x):
    import jax.numpy as jnp

    h = L.relu(L.channel_norm(p["n"], x))
    h = L.conv2d(p["c"], h)
    return jnp.concatenate([x, h], axis=-1)


def init(key, num_classes):
    keys = jax.random.split(key, 40)
    ki = iter(keys)
    params = {"stem": L.init_conv(next(ki), 3, 3, 3, STEM)}
    cin = STEM
    for s in range(4):
        block = []
        for _ in range(LAYERS_PER_BLOCK):
            block.append(_init_dense_layer(next(ki), cin))
            cin += GROWTH
        params[f"block{s + 1}"] = block
        if s < 3:
            cout = cin // 2
            params[f"trans{s + 1}"] = {
                "n": L.init_norm(cin),
                "c": L.init_conv(next(ki), 1, 1, cin, cout),
            }
            cin = cout
    params["head_norm"] = L.init_norm(cin)
    params["fc"] = L.init_dense(next(ki), cin, num_classes)
    return params


def stages(params):
    def make(s):
        def run(x):
            if s == 0:
                x = L.relu(L.conv2d(params["stem"], x))
            for lp in params[f"block{s + 1}"]:
                x = _dense_layer(lp, x)
            if s < 3:
                tp = params[f"trans{s + 1}"]
                x = L.conv2d(tp["c"], L.relu(L.channel_norm(tp["n"], x)))
                x = L.avg_pool(x)
            return x

        return run

    return [make(s) for s in range(4)]


def classifier(params, feat):
    x = L.channel_norm(params["head_norm"], feat)
    x = L.global_avg_pool(L.relu(x))
    return L.dense(params["fc"], x)
