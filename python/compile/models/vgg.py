"""VGG-Mini: plain conv/pool stack (VGG16 analogue).

Four conv stages (two 3×3 convs + maxpool each), widths 32/64/128/128.
"""

from __future__ import annotations

import jax

from .. import layers as L

NAME = "vgg_mini"
SPLITS = [1, 2, 3, 4]
WIDTHS = [32, 64, 128, 128]


def init(key, num_classes):
    keys = jax.random.split(key, 16)
    ki = iter(keys)
    params = {}
    cin = 3
    for s, cout in enumerate(WIDTHS):
        params[f"stage{s + 1}"] = {
            "c1": L.init_conv(next(ki), 3, 3, cin, cout),
            "n1": L.init_norm(cout),
            "c2": L.init_conv(next(ki), 3, 3, cout, cout),
            "n2": L.init_norm(cout),
        }
        cin = cout
    params["fc1"] = L.init_dense(next(ki), WIDTHS[-1] * 2 * 2, 256)
    params["fc2"] = L.init_dense(next(ki), 256, num_classes)
    return params


def stages(params):
    def make(s):
        def run(x):
            p = params[f"stage{s + 1}"]
            x = L.relu(L.channel_norm(p["n1"], L.conv2d(p["c1"], x)))
            x = L.relu(L.channel_norm(p["n2"], L.conv2d(p["c2"], x)))
            return L.max_pool(x)

        return run

    return [make(s) for s in range(4)]


def classifier(params, feat):
    b = feat.shape[0]
    x = feat.reshape(b, -1)
    x = L.relu(L.dense(params["fc1"], x))
    return L.dense(params["fc2"], x)
