"""EfficientNet-Mini: MBConv blocks with squeeze-and-excitation
(EfficientNetB0 analogue).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L

NAME = "efficientnet_mini"
SPLITS = [1, 2, 3, 4]
WIDTHS = [16, 24, 48, 96]
EXPANSION = 4
SE_RATIO = 4


def _init_mbconv(key, cin, cout):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    hidden = cin * EXPANSION
    se_dim = max(1, hidden // SE_RATIO)
    return {
        "expand": L.init_conv(k1, 1, 1, cin, hidden),
        "n1": L.init_norm(hidden),
        "dw": L.init_conv(k2, 3, 3, 1, hidden),
        "n2": L.init_norm(hidden),
        "se_reduce": L.init_dense(k3, hidden, se_dim),
        "se_expand": L.init_dense(k4, se_dim, hidden),
        "project": L.init_conv(k5, 1, 1, hidden, cout),
        "n3": L.init_norm(cout),
    }


def _mbconv(p, x, stride):
    cin = x.shape[-1]
    h = L.silu(L.channel_norm(p["n1"], L.conv2d(p["expand"], x)))
    h = L.silu(L.channel_norm(p["n2"], L.depthwise_conv2d(p["dw"], h, stride=stride)))
    # Squeeze-and-excitation.
    s = L.global_avg_pool(h)
    s = L.silu(L.dense(p["se_reduce"], s))
    s = jax.nn.sigmoid(L.dense(p["se_expand"], s))
    h = h * s[:, None, None, :]
    h = L.channel_norm(p["n3"], L.conv2d(p["project"], h))
    if stride == 1 and cin == h.shape[-1]:
        h = h + x
    return h


def _stride_of(s: int, b: int) -> int:
    return 2 if (b == 0 and s > 0) else 1


def init(key, num_classes):
    keys = jax.random.split(key, 24)
    ki = iter(keys)
    params = {"stem": L.init_conv(next(ki), 3, 3, 3, WIDTHS[0])}
    cin = WIDTHS[0]
    for s, cout in enumerate(WIDTHS):
        blocks = []
        for _b in range(2):
            blocks.append(_init_mbconv(next(ki), cin, cout))
            cin = cout
        params[f"stage{s + 1}"] = blocks
    params["head_norm"] = L.init_norm(WIDTHS[-1])
    params["fc"] = L.init_dense(next(ki), WIDTHS[-1], num_classes)
    return params


def stages(params):
    def make(s):
        def run(x):
            if s == 0:
                x = L.silu(L.conv2d(params["stem"], x))
            for b, bp in enumerate(params[f"stage{s + 1}"]):
                x = _mbconv(bp, x, _stride_of(s, b))
            return x

        return run

    return [make(s) for s in range(4)]


def classifier(params, feat):
    x = L.channel_norm(params["head_norm"], feat)
    x = L.global_avg_pool(x)
    return L.dense(params["fc"], x)


_ = jnp  # silence unused-import lint in minimal builds
