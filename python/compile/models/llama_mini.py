"""Llama-Mini: decoder-only transformer (Llama2 analogue), two sizes.

RMSNorm, causal multi-head attention with learned positions, SwiGLU
MLPs — the Llama block structure at toy scale. The split protocol cuts
the layer stack: the head (embedding + first `sl` blocks) runs on the
edge, the hidden-state IF `(B, T, D)` is compressed and shipped, the
tail (remaining blocks + final norm + lm head) runs on the cloud.

Sizes (the paper's 7B/13B pair, scaled): "s" ≈ 0.9 M params, "m" ≈ 2.6 M.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L

NAME = "llama_mini"

SIZES = {
    "s": {"dim": 128, "layers": 4, "heads": 4, "hidden": 256},
    "m": {"dim": 192, "layers": 6, "heads": 6, "hidden": 384},
}
VOCAB = 512
SEQ_LEN = 64

# Split after this many decoder blocks (≈ middle of the stack, the SC
# operating point for LLM offloading).
def default_split(size: str) -> int:
    return SIZES[size]["layers"] // 2


def _init_block(key, dim, hidden):
    k1, k2 = jax.random.split(key)
    return {
        "n1": {"g": jnp.ones((dim,))},
        "attn": L.init_attention(k1, dim),
        "n2": {"g": jnp.ones((dim,))},
        "mlp": L.init_swiglu(k2, dim, hidden),
    }


def _block(p, x, heads, mask):
    h = x + L.attention(p["attn"], L.rms_norm(p["n1"], x), heads=heads, mask=mask)
    return h + L.swiglu(p["mlp"], L.rms_norm(p["n2"], h))


def init(key, size: str):
    cfg = SIZES[size]
    keys = jax.random.split(key, cfg["layers"] + 3)
    params = {
        "tok_emb": jax.random.normal(keys[0], (VOCAB, cfg["dim"])) * 0.02,
        "pos_emb": jax.random.normal(keys[1], (SEQ_LEN, cfg["dim"])) * 0.02,
        "blocks": [
            _init_block(keys[2 + i], cfg["dim"], cfg["hidden"])
            for i in range(cfg["layers"])
        ],
        "final_norm": {"g": jnp.ones((cfg["dim"],))},
        "lm_head": L.init_dense(keys[-1], cfg["dim"], VOCAB),
    }
    return params


def head_apply(params, tokens, size: str, sl: int):
    """Embedding + first ``sl`` blocks → hidden states (B, T, D)."""
    cfg = SIZES[size]
    x = params["tok_emb"][tokens] + params["pos_emb"][None, : tokens.shape[1]]
    mask = L.causal_mask(tokens.shape[1])
    for p in params["blocks"][:sl]:
        x = _block(p, x, cfg["heads"], mask)
    return x


def tail_apply(params, hidden, size: str, sl: int):
    """Remaining blocks + lm head → logits (B, T, V)."""
    cfg = SIZES[size]
    mask = L.causal_mask(hidden.shape[1])
    x = hidden
    for p in params["blocks"][sl:]:
        x = _block(p, x, cfg["heads"], mask)
    x = L.rms_norm(params["final_norm"], x)
    return L.dense(params["lm_head"], x)


def forward(params, tokens, size: str):
    sl = default_split(size)
    return tail_apply(params, head_apply(params, tokens, size, sl), size, sl)
