"""Split-model protocol shared by the vision zoo.

A model module exposes::

    NAME: str
    SPLITS: list[int]          # valid split layers (1-indexed stage cuts)
    init(key, num_classes) -> params
    stages(params) -> list[callable]   # x -> x, in order
    classifier(params, feat) -> logits

and this module derives full/ head/ tail forward functions from it.
"""

from __future__ import annotations


def forward(model, params, x):
    """Full forward pass (training / baseline accuracy)."""
    for f in model.stages(params):
        x = f(x)
    return model.classifier(params, x)


def head_apply(model, params, x, sl: int):
    """Edge-side head: stages[0:sl]. Returns the intermediate feature."""
    assert sl in model.SPLITS, f"SL{sl} not in {model.SPLITS} for {model.NAME}"
    for f in model.stages(params)[:sl]:
        x = f(x)
    return x


def tail_apply(model, params, feat, sl: int):
    """Cloud-side tail: stages[sl:] + classifier."""
    assert sl in model.SPLITS, f"SL{sl} not in {model.SPLITS} for {model.NAME}"
    for f in model.stages(params)[sl:]:
        feat = f(feat)
    return model.classifier(params, feat)
