"""HLO-text export helpers.

HLO *text* (not serialized HloModuleProto) is the interchange format
between the JAX compile path and the Rust PJRT runtime: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
behind the published ``xla`` crate) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import os

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """Convert a ``jax.jit(...).lower(...)`` result to XLA HLO text.

    Lowers through StableHLO and converts with ``return_tuple=True`` so
    the Rust side can uniformly unwrap tuple outputs.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default HLO printer elides big literals
    # as "{...}", which the text parser reads back as zeros — i.e. every
    # model weight would silently vanish. Full constants are mandatory
    # for the AOT interchange.
    return comp.as_hlo_text(print_large_constants=True)


def export_fn(fn, example_args, out_path: str) -> str:
    """Jit-lower ``fn`` at ``example_args`` and write HLO text.

    Returns the written text. ``example_args`` may be concrete arrays or
    ``jax.ShapeDtypeStruct`` specs.
    """
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        f.write(text)
    return text
