"""Functional NN layer library (Layer 2 building blocks).

Parameters are nested dicts of jnp arrays; every layer is a pure
function so heads/tails lower cleanly to HLO. Normalization is
stateless (LayerNorm over channels) so train and eval graphs are
identical — no running statistics to thread through the AOT export.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- init

def he_conv(key, kh, kw, cin, cout):
    """He-normal conv kernel (HWIO)."""
    std = math.sqrt(2.0 / (kh * kw * cin))
    return jax.random.normal(key, (kh, kw, cin, cout)) * std


def glorot_dense(key, din, dout):
    """Glorot-uniform dense kernel."""
    lim = math.sqrt(6.0 / (din + dout))
    return jax.random.uniform(key, (din, dout), minval=-lim, maxval=lim)


def init_conv(key, kh, kw, cin, cout):
    return {"w": he_conv(key, kh, kw, cin, cout), "b": jnp.zeros((cout,))}


def init_dense(key, din, dout):
    return {"w": glorot_dense(key, din, dout), "b": jnp.zeros((dout,))}


def init_norm(dim):
    return {"g": jnp.ones((dim,)), "b": jnp.zeros((dim,))}


# -------------------------------------------------------------- layers

def conv2d(p, x, stride=1, padding="SAME", groups=1):
    """NHWC conv with bias."""
    y = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    return y + p["b"]


def depthwise_conv2d(p, x, stride=1):
    """Depthwise conv: kernel (kh, kw, 1, C) with groups=C."""
    c = x.shape[-1]
    return conv2d(p, x, stride=stride, groups=c)


def dense(p, x):
    return x @ p["w"] + p["b"]


def channel_norm(p, x, eps=1e-5):
    """LayerNorm over the trailing (channel) axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def rms_norm(p, x, eps=1e-6):
    """RMSNorm (llama-style); params carry only the gain."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * p["g"]


def relu(x):
    return jnp.maximum(x, 0.0)


def gelu(x):
    return jax.nn.gelu(x)


def silu(x):
    return x * jax.nn.sigmoid(x)


def max_pool(x, size=2, stride=2):
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        (1, size, size, 1),
        (1, stride, stride, 1),
        "VALID",
    )


def avg_pool(x, size=2, stride=2):
    summed = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, size, size, 1), (1, stride, stride, 1), "VALID"
    )
    return summed / float(size * size)


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


# ----------------------------------------------------------- attention

def init_attention(key, dim):
    k1, k2 = jax.random.split(key)
    return {
        "qkv": init_dense(k1, dim, dim * 3),
        "proj": init_dense(k2, dim, dim),
    }


def attention(p, x, heads, mask=None):
    """Multi-head self-attention over (..., T, D). ``heads`` is static."""
    *lead, t, d = x.shape
    h = heads
    hd = d // h
    qkv = dense(p["qkv"], x)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def split_heads(a):
        return a.reshape(*lead, t, h, hd).swapaxes(-3, -2)  # (..., h, T, hd)

    q, k, v = split_heads(q), split_heads(k), split_heads(v)
    att = (q @ k.swapaxes(-1, -2)) / math.sqrt(hd)
    if mask is not None:
        att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = att @ v  # (..., h, T, hd)
    out = out.swapaxes(-3, -2).reshape(*lead, t, d)
    return dense(p["proj"], out)


def causal_mask(t):
    return jnp.tril(jnp.ones((t, t), bool))


# --------------------------------------------------------------- misc

def init_mlp(key, dim, hidden):
    k1, k2 = jax.random.split(key)
    return {"fc1": init_dense(k1, dim, hidden), "fc2": init_dense(k2, hidden, dim)}


def mlp(p, x, act=gelu):
    return dense(p["fc2"], act(dense(p["fc1"], x)))


def init_swiglu(key, dim, hidden):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, dim, hidden),
        "up": init_dense(k2, dim, hidden),
        "down": init_dense(k3, hidden, dim),
    }


def swiglu(p, x):
    return dense(p["down"], silu(dense(p["gate"], x)) * dense(p["up"], x))


def count_params(tree) -> int:
    """Total parameter count of a params pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(a.size for a in leaves if hasattr(a, "size")))
